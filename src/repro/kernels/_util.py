"""Leaf-module helpers shared by the kernel entry points.

Lives below the package __init__ so submodules can import it without a
cycle through ``repro.kernels``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """True iff Pallas kernels should run in interpret mode (no accelerator).

    Mosaic lowering needs a TPU (or Triton a GPU); on the CPU backend the
    same kernels run under the Pallas interpreter. Call sites pass
    ``interpret=None`` and let this decide.
    """
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret) -> bool:
    """None -> backend default; bool -> as given (explicit override)."""
    return default_interpret() if interpret is None else bool(interpret)


def round_up(n: int, multiple: int) -> int:
    """n rounded up to the next multiple."""
    return n + (-n) % multiple


def pad_tail(x, npad: int, fill):
    """Pad the last axis of x to length npad with a neutral fill value.

    The fill must be inert for the consuming kernel (inactive entry,
    +inf seed, zero weight); callers slice results back to the true n.
    """
    pad = npad - x.shape[-1]
    if not pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=fill)
