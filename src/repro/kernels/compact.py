"""Compaction/merge kernel for fixed-capacity MultiSketch wire slabs.

Compacting S^(F) ∪ Z into ``capacity`` slots is a selection problem in
disguise: assign every entry a retention PRIORITY (members first, then aux,
each ordered by weight descending; dropped/duplicate/invalid entries +inf)
and take the ``capacity`` smallest priorities. That reuses the PR 1 batched
block-select kernel for the take, so the only new device code is the fused
priority pass implemented here:

  one VMEM-resident sweep computes, per entry,
    dup    — key equals the previous key (inputs are key-sorted with
             weight-descending tiebreak, so the FIRST occurrence carries the
             max weight: the paper's w_x = max rule for merged data sets)
    pri    — member: w/(1+w) mapped to (0,1]   via 1/(1+w)
             aux:    2 + 1/(1+w) in (2,3]
             else    +inf
  i.e. one HBM read of (keys, prev_keys, member, keep, w) and one write of
  the f32 priority row — the merge path's only elementwise full pass.

``compact_take`` chains this with ``bottomk_select`` (Pallas block-select +
one top_k merge) to emit gather indices for the compacted slab.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels._util import pad_tail, resolve_interpret, round_up
from repro.kernels.blockselect import bottomk_select

BLOCK = 1024
_INF = np.float32(np.inf)


def _priority_kernel(keys_ref, prev_ref, member_ref, keep_ref, w_ref,
                     out_ref):
    k = keys_ref[...]
    dup = (k == prev_ref[...]) | (k < 0)
    keep = (keep_ref[...] != 0) & ~dup
    member = member_ref[...] != 0
    w = jnp.maximum(w_ref[...].astype(jnp.float32), 0.0)
    inv = 1.0 / (1.0 + w)                       # weight desc -> pri asc
    pri = jnp.where(member, inv, np.float32(2.0) + inv)
    out_ref[...] = jnp.where(keep, pri, _INF)


@partial(jax.jit, static_argnames=("interpret",))
def retention_priority(sorted_keys, weights, member, keep, interpret=None):
    """Fused dedup + retention-priority pass (one launch).

    Inputs must be sorted by (key asc, weight desc); duplicate keys (all but
    the first, max-weight occurrence) and negative keys (empty slots) get
    priority +inf, as do entries with ``keep`` False. Returns pri [n] f32
    whose ascending order is: members by weight desc, then aux by weight
    desc, then dropped.
    """
    interpret = resolve_interpret(interpret)
    n = sorted_keys.shape[0]
    # delta-slab sizing: absorb-time maintenance re-selects over a few
    # hundred retained slots ((1 + dirty) x capacity) every epoch, not a
    # streaming batch — fit the block to the input (lane-aligned) instead
    # of padding every call to the full streaming BLOCK. Splitting the
    # grid first keeps the pad under one lane-quantum per block (n=1100:
    # 2 x 640 = 1280 padded rows, vs 2048 when clamping to BLOCK); the
    # kernel is elementwise and pad rows are sliced off, so sizing never
    # affects the retained bits.
    g = -(-max(n, 1) // BLOCK)
    b = min(BLOCK, round_up(-(-max(n, 1) // g), 128))
    npad = round_up(max(n, 1), b)
    sk = pad_tail(sorted_keys.astype(jnp.int32), npad, -1)
    prev = jnp.concatenate([jnp.full((1,), -2, jnp.int32), sk[:-1]])
    w = pad_tail(weights.astype(jnp.float32), npad, 0.0)
    mem = pad_tail(member.astype(jnp.int32), npad, 0)
    kp = pad_tail(keep.astype(jnp.int32), npad, 0)
    out = pl.pallas_call(
        _priority_kernel,
        grid=(npad // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))] * 5,
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(sk, prev, mem, kp, w)
    return out[:n]


def compact_take(sorted_keys, weights, member, keep, capacity: int,
                 interpret=None):
    """Gather indices compacting retained entries into ``capacity`` slots.

    Returns (take [capacity] int32, taken_valid [capacity] bool): positions
    of the ``capacity`` highest-retention entries (members by weight desc,
    then aux), -1 / False on slots past the retained count. Exact via the
    two-level block-select (the capacity smallest priorities).
    """
    pri = retention_priority(sorted_keys, weights, member, keep,
                             interpret=interpret)
    n = pri.shape[0]
    if n < capacity + 1:  # block-select needs >= capacity+1 candidates
        pri = pad_tail(pri, capacity + 1, _INF)
    vals, idx, _tau = bottomk_select(pri, capacity, interpret=interpret)
    valid = jnp.isfinite(vals) & (idx >= 0) & (idx < n)
    return jnp.where(valid, idx, -1).astype(jnp.int32), valid
