"""Fused f-seed computation kernel (paper §2.2 hot loop).

Computing a multi-objective sample applies |F| functions to every element
(paper §3.3: Omega(|F| n) lower bound). The reference path materializes
u_x, r_x and each f(w_x) in HBM separately; this kernel fuses
hash -> u -> r -> { r / f_j(w) } for all objectives into one VMEM-resident
pass: each (8x128-aligned) block of keys/weights is read once from HBM and
|F| seed rows are written once — the arithmetic-intensity fix for what is
otherwise a purely bandwidth-bound loop.

``fused_seeds_fvals`` additionally emits the f-values f_j(w_x) themselves
(already computed inside the kernel for the seed division), so the
downstream conditional-probability step of the batched multi-objective
pipeline needs no per-objective recomputation on the host.

Objectives are compiled in as (kind, param) pairs: kind 0=sum, 1=count,
2=thresh(T), 3=cap(T), 4=moment(p).

Inputs of any length are auto-padded to a BLOCK multiple with inactive
entries (seed = +inf, fval = 0) and the outputs sliced back to n.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels._util import pad_tail, resolve_interpret, round_up

_GOLDEN = np.uint32(0x9E3779B9)  # numpy scalars fold into the kernel
BLOCK = 1024  # 8 sublanes x 128 lanes


def _mix(h):
    h = h ^ (h >> 16)
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _fval(kind: int, param: float, w):
    if kind == 0:
        return w
    if kind == 1:
        return (w > 0).astype(jnp.float32)
    if kind == 2:
        return (w >= param).astype(jnp.float32)
    if kind == 3:
        return jnp.minimum(w, param)
    return jnp.where(w > 0, jnp.power(jnp.maximum(w, 1e-30), param), 0.0)


def _seeds_kernel(keys_ref, w_ref, act_ref, *out_refs, objectives,
                  scheme: str, seed: int, want_fvals: bool):
    out_ref = out_refs[0]
    k = keys_ref[...].astype(jnp.uint32)
    w = w_ref[...].astype(jnp.float32)
    act = act_ref[...] != 0
    c1 = np.uint32((0x9E3779B9 + seed) & 0xFFFFFFFF)
    c2 = np.uint32((seed * 0x85EBCA6B + 1) & 0xFFFFFFFF)
    h = _mix(k + c1)
    h = _mix(h ^ c2)
    u = (h >> np.uint32(8)).astype(jnp.float32) * np.float32(1.0 / (1 << 24))
    u = u + np.float32(0.5 / (1 << 24))
    r = -jnp.log1p(-u) if scheme == "ppswor" else u
    for j, (kind, param) in enumerate(objectives):
        fv = _fval(kind, param, w)
        ok = act & (fv > 0)
        out_ref[j, :] = jnp.where(ok, r / jnp.maximum(fv, 1e-30),
                                  jnp.float32(jnp.inf))
        if want_fvals:
            out_refs[1][j, :] = jnp.where(act, fv, 0.0)


@partial(jax.jit, static_argnames=("objectives", "scheme", "seed",
                                   "interpret", "want_fvals"))
def _fused_seeds(keys, weights, active, objectives, scheme, seed,
                 interpret, want_fvals: bool):
    if scheme not in ("ppswor", "priority"):
        raise ValueError(
            f"unknown scheme {scheme!r} (want 'priority' or 'ppswor')")
    interpret = resolve_interpret(interpret)
    n = keys.shape[0]
    npad = round_up(n, BLOCK)
    keys = pad_tail(keys.astype(jnp.int32), npad, 0)
    weights = pad_tail(weights.astype(jnp.float32), npad, 0.0)
    act = pad_tail(active.astype(jnp.int32), npad, 0)
    nf = len(objectives)
    grid = (npad // BLOCK,)
    out_specs = [pl.BlockSpec((nf, BLOCK), lambda i: (0, i))]
    out_shape = [jax.ShapeDtypeStruct((nf, npad), jnp.float32)]
    if want_fvals:
        out_specs.append(pl.BlockSpec((nf, BLOCK), lambda i: (0, i)))
        out_shape.append(jax.ShapeDtypeStruct((nf, npad), jnp.float32))
    outs = pl.pallas_call(
        partial(_seeds_kernel, objectives=tuple(objectives), scheme=scheme,
                seed=seed, want_fvals=want_fvals),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(keys, weights, act)
    if want_fvals:
        return outs[0][:, :n], outs[1][:, :n]
    return outs[0][:, :n]


def fused_seeds(keys, weights, active, objectives, scheme="ppswor", seed=0,
                interpret=None):
    """keys,(weights,active): [n] -> seeds [|F|, n]; any n (auto-padded).

    objectives: tuple of (kind:int, param:float).
    """
    return _fused_seeds(keys, weights, active, tuple(objectives), scheme,
                        seed, interpret, False)


def fused_seeds_fvals(keys, weights, active, objectives, scheme="ppswor",
                      seed=0, interpret=None):
    """Like :func:`fused_seeds` but returns (seeds [|F|,n], fvals [|F|,n]).

    fvals[j] = f_j(w) masked to 0 on inactive keys — exactly the values the
    conditional-probability step (core.bottomk.conditional_prob) consumes,
    produced in the same single launch (one extra VMEM->HBM write, no extra
    read).
    """
    return _fused_seeds(keys, weights, active, tuple(objectives), scheme,
                        seed, interpret, True)
