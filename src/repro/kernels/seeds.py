"""Fused f-seed computation kernel (paper §2.2 hot loop).

Computing a multi-objective sample applies |F| functions to every element
(paper §3.3: Omega(|F| n) lower bound). The reference path materializes
u_x, r_x and each f(w_x) in HBM separately; this kernel fuses
hash -> u -> r -> { r / f_j(w) } for all objectives into one VMEM-resident
pass: each (8x128-aligned) block of keys/weights is read once from HBM and
|F| seed rows are written once — the arithmetic-intensity fix for what is
otherwise a purely bandwidth-bound loop.

Objectives are compiled in as (kind, param) pairs: kind 0=sum, 1=count,
2=thresh(T), 3=cap(T), 4=moment(p).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_GOLDEN = np.uint32(0x9E3779B9)  # numpy scalars fold into the kernel
BLOCK = 1024  # 8 sublanes x 128 lanes


def _mix(h):
    h = h ^ (h >> 16)
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _fval(kind: int, param: float, w):
    if kind == 0:
        return w
    if kind == 1:
        return (w > 0).astype(jnp.float32)
    if kind == 2:
        return (w >= param).astype(jnp.float32)
    if kind == 3:
        return jnp.minimum(w, param)
    return jnp.where(w > 0, jnp.power(jnp.maximum(w, 1e-30), param), 0.0)


def _seeds_kernel(keys_ref, w_ref, act_ref, out_ref, *, objectives,
                  scheme: str, seed: int):
    k = keys_ref[...].astype(jnp.uint32)
    w = w_ref[...].astype(jnp.float32)
    act = act_ref[...] != 0
    c1 = np.uint32((0x9E3779B9 + seed) & 0xFFFFFFFF)
    c2 = np.uint32((seed * 0x85EBCA6B + 1) & 0xFFFFFFFF)
    h = _mix(k + c1)
    h = _mix(h ^ c2)
    u = (h >> np.uint32(8)).astype(jnp.float32) * np.float32(1.0 / (1 << 24))
    u = u + np.float32(0.5 / (1 << 24))
    r = -jnp.log1p(-u) if scheme == "ppswor" else u
    for j, (kind, param) in enumerate(objectives):
        fv = _fval(kind, param, w)
        ok = act & (fv > 0)
        out_ref[j, :] = jnp.where(ok, r / jnp.maximum(fv, 1e-30),
                                  jnp.float32(jnp.inf))


@partial(jax.jit, static_argnames=("objectives", "scheme", "seed",
                                   "interpret"))
def fused_seeds(keys, weights, active, objectives, scheme="ppswor", seed=0,
                interpret=True):
    """keys,(weights,active): [n] -> seeds [|F|, n]. n must divide BLOCK.

    objectives: tuple of (kind:int, param:float).
    """
    n = keys.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of {BLOCK}"
    nf = len(objectives)
    grid = (n // BLOCK,)
    return pl.pallas_call(
        partial(_seeds_kernel, objectives=tuple(objectives), scheme=scheme,
                seed=seed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((nf, BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nf, n), jnp.float32),
        interpret=interpret,
    )(keys.astype(jnp.int32), weights.astype(jnp.float32),
      active.astype(jnp.int32))
