"""Blocked pairwise rank-count kernel — universal sample membership.

Membership in the universal samples is a rank condition (DESIGN.md §3):
  monotone (Lemma 5.1):  x in S^(M,k)  <=>  h_x < k,
      h_x = #{y : w_y >= w_x  and  u_y < u_x}
  capping  (Lemma 6.3):  x in S^(C,k)  <=>  h_x + l_x < k,
      l_x = #{y : w_y <  w_x  and  r_y/w_y < r_x/w_x}

The paper's heap algorithms are sequential; the TPU-native batch form is a
blocked all-pairs count: grid (nx, ny), each step loads an x-block and a
y-block into VMEM and accumulates counts for the x-block. O(n^2 / B) work
but entirely VMEM-resident, VPU-aligned tiles, zero HBM intermediates — for
the n <= 2^20 per-training-step uses (gradient compression, telemetry) this
beats the sort path's all-to-HBM round trips.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._util import pad_tail, resolve_interpret, round_up

BLOCK_X = 512
BLOCK_Y = 1024


def _rankcount_kernel(wx_ref, hx_ref, lx_ref, ax_ref,
                      wy_ref, hy_ref, ly_ref, ay_ref,
                      h_ref, l_ref):
    """Accumulate h and l for the x-block against one y-block.

    h uses the u-statistic (hx/hy); l uses the r/w-statistic (lx/ly).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    wx = wx_ref[...].astype(jnp.float32)[:, None]   # [BX,1]
    shx = hx_ref[...].astype(jnp.float32)[:, None]
    slx = lx_ref[...].astype(jnp.float32)[:, None]
    ax = ax_ref[...][:, None] != 0
    wy = wy_ref[...].astype(jnp.float32)[None, :]   # [1,BY]
    shy = hy_ref[...].astype(jnp.float32)[None, :]
    sly = ly_ref[...].astype(jnp.float32)[None, :]
    ay = ay_ref[...][None, :] != 0

    both = ax & ay
    ge = both & (wy >= wx) & (shy < shx)
    lt = both & (wy < wx) & (sly < slx)
    h_ref[...] += jnp.sum(ge, axis=1).astype(jnp.int32)
    l_ref[...] += jnp.sum(lt, axis=1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def rank_counts(weights, s_h, s_l, active, interpret=None):
    """Returns (h, l) int32 [n]; h vs order stat s_h (u), l vs s_l (r/w).

    Ragged n is auto-padded with inactive entries (never counted on either
    side of a pair) and the counts sliced back. The diagonal never
    self-counts: the strict comparison s_y < s_x is false at y == x.
    """
    interpret = resolve_interpret(interpret)
    n = weights.shape[0]
    # n <= BLOCK_X fits a (1, 1) grid unpadded; otherwise round up to a
    # BLOCK_Y multiple (also a BLOCK_X multiple since BLOCK_X | BLOCK_Y).
    npad = n if n <= BLOCK_X else round_up(n, BLOCK_Y)
    bx = min(BLOCK_X, npad)
    by = min(BLOCK_Y, npad)
    w32 = pad_tail(weights.astype(jnp.float32), npad, 0.0)
    sh32 = pad_tail(s_h.astype(jnp.float32), npad, 0.0)
    sl32 = pad_tail(s_l.astype(jnp.float32), npad, 0.0)
    a32 = pad_tail(active.astype(jnp.int32), npad, 0)
    grid = (npad // bx, npad // by)

    xspec = lambda b: pl.BlockSpec((b,), lambda i, j: (i,))
    yspec = lambda b: pl.BlockSpec((b,), lambda i, j: (j,))
    h, l = pl.pallas_call(
        _rankcount_kernel,
        grid=grid,
        in_specs=[xspec(bx), xspec(bx), xspec(bx), xspec(bx),
                  yspec(by), yspec(by), yspec(by), yspec(by)],
        out_specs=[pl.BlockSpec((bx,), lambda i, j: (i,)),
                   pl.BlockSpec((bx,), lambda i, j: (i,))],
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.int32),
                   jax.ShapeDtypeStruct((npad,), jnp.int32)],
        interpret=interpret,
    )(w32, sh32, sl32, a32, w32, sh32, sl32, a32)
    return h[:n], l[:n]
