"""High-level jit'd entry points composing the Pallas kernels into the
paper's sampling operations. ``interpret=None`` auto-detects the backend
(interpret mode on CPU, compiled Mosaic on TPU); pass an explicit bool to
override.

The multi-objective path is a single-launch batched chain (paper §3.3:
one summary for Omega(|F| n) work):

  fused_seeds_fvals   ONE launch   -> seeds [F, n], fvals [F, n]
  batched blockselect ONE launch   -> candidates [F, nb*(k+1)]
  batched top_k merge ONE scan     -> kth/tau per objective
  membership + conditional prob + max over F: vectorized [F, n] jnp ops

No Python loop over objectives anywhere — launch count and scan count are
flat in |F|; only the O(|F| n) bandwidth term remains, which is the
paper's lower bound.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bottomk import conditional_prob
from repro.core.funcs import StatFn
from repro.core.hashing import rank_of, uniform01
from .blockselect import batched_bottomk_select
from .rankcount import rank_counts
from .seeds import fused_seeds_fvals

# objective encoding for the seeds kernel
SUM, COUNT, THRESH, CAP, MOMENT = 0, 1, 2, 3, 4

_KIND_NAMES = {0: "sum", 1: "count", 2: "thresh", 3: "cap", 4: "moment"}


def statfn_of(kind: int, param: float) -> StatFn:
    """The core StatFn equivalent of a (kind, param) kernel objective."""
    return StatFn(_KIND_NAMES[kind], float(param))


@partial(jax.jit, static_argnames=("objectives", "k", "scheme", "seed",
                                   "interpret"))
def multi_objective_bottomk_kernel(keys, weights, active, objectives,
                                   k: int, scheme="ppswor", seed=0,
                                   interpret=None):
    """Multi-objective bottom-k sample S^(F) via the fused batched kernels.

    Returns (member [n] bool, prob [n] float32) — same semantics as
    core.multi_objective.multi_bottomk_sample (member/prob only), with a
    launch count independent of |F|.
    """
    n = keys.shape[0]
    kk = min(k, n)
    seeds, fvals = fused_seeds_fvals(keys, weights, active, objectives,
                                     scheme, seed, interpret=interpret)
    vals, _idx, tau = batched_bottomk_select(seeds, kk, interpret=interpret)
    kth = vals[:, kk - 1]                                  # [F]
    member_f = (seeds <= kth[:, None]) & jnp.isfinite(seeds)
    p_f = jnp.where(member_f,
                    conditional_prob(fvals, tau[:, None], scheme), 0.0)
    return member_f.any(axis=0), p_f.max(axis=0)


@partial(jax.jit, static_argnames=("k", "scheme", "seed", "interpret"))
def universal_capping_kernel(keys, weights, active, k: int, scheme="ppswor",
                             seed=0, interpret=None):
    """S^(C,k) membership via the blocked rank-count kernel (Lemma 6.3).

    Returns (member, hl) — membership exact; probabilities follow the
    candidate pass of core.capping (host side, |candidates| x |candidates|).
    """
    w = jnp.asarray(weights, jnp.float32)
    act = jnp.asarray(active, bool) & (w > 0)
    u = uniform01(keys, seed)
    r = rank_of(u, scheme)
    rw = jnp.where(act, r / jnp.maximum(w, 1e-30), jnp.float32(jnp.inf))
    # h uses u as the order statistic; l uses r/w  (DESIGN.md §3)
    h, l = rank_counts(jnp.where(act, w, 0.0), u, rw, act,
                       interpret=interpret)
    hl = h + l
    return act & (hl < k), jnp.minimum(hl, k + 1)
