"""High-level jit'd entry points composing the Pallas kernels into the
paper's sampling operations. On a real TPU set interpret=False; on CPU the
kernels run in interpret mode (same program, python-evaluated)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bottomk import conditional_prob
from repro.core.hashing import rank_of, uniform01
from .blockselect import bottomk_select
from .rankcount import rank_counts
from .seeds import fused_seeds

# objective encoding for the seeds kernel
SUM, COUNT, THRESH, CAP, MOMENT = 0, 1, 2, 3, 4


@partial(jax.jit, static_argnames=("objectives", "k", "scheme", "seed",
                                   "interpret"))
def multi_objective_bottomk_kernel(keys, weights, active, objectives,
                                   k: int, scheme="ppswor", seed=0,
                                   interpret=True):
    """Multi-objective bottom-k sample S^(F) via the fused kernels.

    Returns (member [n] bool, prob [n] float32) — same semantics as
    core.multi_objective.multi_bottomk_sample (member/prob only).
    """
    n = keys.shape[0]
    seeds = fused_seeds(keys, weights, active, objectives, scheme, seed,
                        interpret=interpret)                  # [F, n]
    member = jnp.zeros((n,), bool)
    prob = jnp.zeros((n,), jnp.float32)
    for j, (kind, param) in enumerate(objectives):
        vals, idx, tau = bottomk_select(seeds[j], k, interpret=interpret)
        m = jnp.zeros((n,), bool).at[jnp.where(idx >= 0, idx, n)].set(
            True, mode="drop")
        from repro.core.funcs import StatFn
        kindname = {0: "sum", 1: "count", 2: "thresh", 3: "cap",
                    4: "moment"}[kind]
        f = StatFn(kindname, float(param))
        fv = jnp.where(active, f(jnp.asarray(weights, jnp.float32)), 0.0)
        p = jnp.where(m, conditional_prob(fv, tau, scheme), 0.0)
        member = member | m
        prob = jnp.maximum(prob, p)
    return member, prob


@partial(jax.jit, static_argnames=("k", "scheme", "seed", "interpret"))
def universal_capping_kernel(keys, weights, active, k: int, scheme="ppswor",
                             seed=0, interpret=True):
    """S^(C,k) membership via the blocked rank-count kernel (Lemma 6.3).

    Returns (member, hl) — membership exact; probabilities follow the
    candidate pass of core.capping (host side, |candidates| x |candidates|).
    """
    w = jnp.asarray(weights, jnp.float32)
    act = jnp.asarray(active, bool) & (w > 0)
    u = uniform01(keys, seed)
    r = rank_of(u, scheme)
    rw = jnp.where(act, r / jnp.maximum(w, 1e-30), jnp.float32(jnp.inf))
    # h uses u as the order statistic; l uses r/w  (DESIGN.md §3)
    h, l = rank_counts(jnp.where(act, w, 0.0), u, rw, act,
                       interpret=interpret)
    hl = h + l
    return act & (hl < k), jnp.minimum(hl, k + 1)
