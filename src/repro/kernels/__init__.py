"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §3).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated in
interpret=True mode against the pure-jnp oracle in ref.py; ops.py exposes
the jit'd compositions.

``interpret`` resolution: every kernel entry point takes ``interpret=None``
and resolves it via :func:`default_interpret` — interpret (python-evaluated)
mode on CPU, compiled Mosaic on TPU/GPU — so call sites never hardcode the
backend.
"""
from ._util import default_interpret, resolve_interpret
from .seeds import fused_seeds, fused_seeds_fvals
from .rankcount import rank_counts
from .blockselect import (
    batched_block_bottomk, batched_bottomk_select, block_bottomk,
    bottomk_select)
from .compact import compact_take, retention_priority
from .segquery import segment_query_slab
from .servicecost import service_cost_slab
from . import ops, ref

__all__ = ["fused_seeds", "fused_seeds_fvals", "rank_counts",
           "block_bottomk", "bottomk_select", "batched_block_bottomk",
           "batched_bottomk_select", "compact_take", "retention_priority",
           "segment_query_slab", "service_cost_slab",
           "default_interpret", "resolve_interpret", "ops", "ref"]
