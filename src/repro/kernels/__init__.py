"""Pallas TPU kernels for the paper's compute hot spots (DESIGN.md §3).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated in
interpret=True mode against the pure-jnp oracle in ref.py; ops.py exposes
the jit'd compositions.
"""
from .seeds import fused_seeds
from .rankcount import rank_counts
from .blockselect import block_bottomk, bottomk_select
from . import ops, ref

__all__ = ["fused_seeds", "rank_counts", "block_bottomk", "bottomk_select",
           "ops", "ref"]
