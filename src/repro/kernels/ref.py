"""Pure-jnp oracles for every kernel in this package (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.funcs import StatFn
from repro.core.hashing import rank_of, uniform01

_KIND_TO_STATFN = {0: ("sum",), 1: ("count",), 2: ("thresh",),
                   3: ("cap",), 4: ("moment",)}


def fused_seeds_ref(keys, weights, active, objectives, scheme="ppswor",
                    seed=0):
    """Oracle for kernels.seeds.fused_seeds."""
    u = uniform01(keys, seed)
    r = rank_of(u, scheme)
    act = jnp.asarray(active, bool)
    out = []
    for kind, param in objectives:
        f = StatFn(_KIND_TO_STATFN[kind][0], float(param))
        fv = f(jnp.asarray(weights, jnp.float32))
        ok = act & (fv > 0)
        out.append(jnp.where(ok, r / jnp.maximum(fv, 1e-30),
                             jnp.float32(jnp.inf)))
    return jnp.stack(out)


def fused_seeds_fvals_ref(keys, weights, active, objectives,
                          scheme="ppswor", seed=0):
    """Oracle for kernels.seeds.fused_seeds_fvals (seeds AND f-values)."""
    act = jnp.asarray(active, bool)
    w = jnp.asarray(weights, jnp.float32)
    fvals = jnp.stack([
        jnp.where(act, StatFn(_KIND_TO_STATFN[kind][0], float(param))(w), 0.0)
        for kind, param in objectives])
    return fused_seeds_ref(keys, weights, active, objectives, scheme,
                           seed), fvals


def batched_bottomk_select_ref(seeds, k: int):
    """Oracle for kernels.blockselect.batched_bottomk_select ([F, n] rows)."""
    n = seeds.shape[-1]
    neg, idx = jax.lax.top_k(-jnp.asarray(seeds, jnp.float32), min(k + 1, n))
    vals = -neg
    tau = (vals[:, k] if n > k
           else jnp.full(seeds.shape[:-1], jnp.inf, jnp.float32))
    iv = jnp.where(jnp.isfinite(vals[:, :k]), idx[:, :k], -1)
    return vals[:, :k], iv.astype(jnp.int32), tau


def segment_query_ref(keys, weights, probs, member, table, objectives):
    """Oracle for kernels.segquery.segment_query_slab: [|F|, B] estimates
    via the shared predicate oracle + the batched HT estimator."""
    from repro.core.estimators import estimate_many
    from repro.core.predicates import predicate_matrix
    fs = [StatFn(_KIND_TO_STATFN[kind][0], float(param))
          for kind, param in objectives]
    sel = predicate_matrix(keys, table)
    return estimate_many(fs, jnp.asarray(weights, jnp.float32), probs,
                         member, sel)


def service_cost_ref(points, probs, member, table, point_weights=None):
    """Oracle for kernels.servicecost.service_cost_slab: [Q] HT estimates
    via the shared cost-value oracle + the batched HT estimator."""
    from repro.core.costs import service_cost_values
    from repro.core.estimators import estimate_many
    from repro.core.funcs import SUM
    pts = jnp.asarray(points, jnp.float32)
    values = service_cost_values(pts, table)
    pw = (jnp.ones(pts.shape[:1], jnp.float32) if point_weights is None
          else jnp.asarray(point_weights, jnp.float32))
    return estimate_many((SUM,), pw, probs, member, values)[0]


def rank_counts_ref(weights, s_h, s_l, active):
    """Oracle for kernels.rankcount.rank_counts. O(n^2)."""
    w = jnp.asarray(weights, jnp.float32)
    sh = jnp.asarray(s_h, jnp.float32)
    sl = jnp.asarray(s_l, jnp.float32)
    act = jnp.asarray(active, bool)
    pair_h = (act[None, :] & act[:, None] & (sh[None, :] < sh[:, None]))
    pair_l = (act[None, :] & act[:, None] & (sl[None, :] < sl[:, None]))
    h = jnp.sum(pair_h & (w[None, :] >= w[:, None]), axis=1)
    l = jnp.sum(pair_l & (w[None, :] < w[:, None]), axis=1)
    return h.astype(jnp.int32), l.astype(jnp.int32)


def block_bottomk_ref(seeds, k: int, block: int):
    """Oracle for kernels.blockselect.block_bottomk."""
    n = seeds.shape[0]
    nb = n // block
    s = jnp.asarray(seeds, jnp.float32).reshape(nb, block)
    neg, pos = jax.lax.top_k(-s, k)
    vals = -neg
    idx = pos + (jnp.arange(nb) * block)[:, None]
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    return vals.reshape(-1), idx.reshape(-1).astype(jnp.int32)


def bottomk_select_ref(seeds, k: int):
    """Oracle for kernels.blockselect.bottomk_select (exact global)."""
    n = seeds.shape[0]
    neg, idx = jax.lax.top_k(-jnp.asarray(seeds, jnp.float32),
                             min(k + 1, n))
    vals = -neg
    tau = vals[k] if n > k else jnp.float32(jnp.inf)
    iv = jnp.where(jnp.isfinite(vals[:k]), idx[:k], -1)
    return vals[:k], iv.astype(jnp.int32), tau
