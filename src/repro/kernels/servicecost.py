"""Fused service-cost kernel: Q center sets x sample slab, ONE launch.

Center-set optimization (launch.cluster) scores thousands of candidate
sets per local-search round; evaluated one set at a time each candidate
pays a kernel launch plus an O(c) pass over the resident sample slab.
This kernel fuses the whole Q-batch into one VMEM-resident launch:

  per slab block of 128 slots (ONE HBM read of coords/probs/member):
    ht      [128]            member ? w / p : 0        (HT weight, Eq. 5)
    d2      [Q*Cmax, 128]    squared distances of every center of every
                             candidate set to the block's points — ONE
                             MXU contraction (centers ride the sublane
                             axis, slab slots the lane axis)
    mind2   [Q, 128]         min over each set's Cmax center slots
    fv      [Q, 128]         mind2^(mu/2)  (cost mode, per-set mu row)
                             or 1[mind2 <= r^2]  (ball mode, per-set r)
    out    += fv * ht        [Q, 128] per-lane partial sums

and the [Q, 128] accumulator is reduced to [Q] once at the end. Launch
count is flat in both Q and Cmax — only the O(c) slab-bandwidth term and
the O(Q Cmax) MXU work scale. Q pads to the sublane quantum (8), dim to 8,
slots to 128; invalid center slots (ragged sets, padded rows) are masked
to +inf before the min, so an all-invalid row estimates exactly 0 (the
``pad_cost_table`` padding element).

Wire semantics are defined by ``core.costs.service_cost_values`` (the XLA
oracle); both paths share the quadratic distance expansion
d2 = |x|^2 + |c|^2 - 2 x.c clamped at 0, so they agree to float tolerance.

VMEM note: the distance block is [Q*Cmax, 128] f32 — 4 MB at the largest
supported batch (Q=128, Cmax=64); callers wanting bigger batches split Q.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.costs import MODE_BALL, CostTable, encode_cost_queries
from repro.kernels._util import pad_tail, resolve_interpret, round_up

BLOCK = 128      # slab slots per grid step (one lane tile)
_SUBLANES = 8    # Q and dim padding quantum


def _servicecost_kernel(pts_ref, ht_ref, ctr_ref, cv_ref, mu_ref, r_ref,
                        mode_ref, out_ref, *, qpad, cmax):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pts = pts_ref[...]                                  # [dpad, 128]
    ht = ht_ref[...]                                    # [128]
    ctr = ctr_ref[...]                                  # [Q*Cmax, dpad]
    cv = cv_ref[...] != 0                               # [Q*Cmax]

    # squared distances, one MXU contraction for every (set, center) row
    dots = jax.lax.dot_general(ctr, pts, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    cn2 = jnp.sum(ctr * ctr, axis=1)                    # [Q*Cmax]
    pn2 = jnp.sum(pts * pts, axis=0)                    # [128]
    d2 = jnp.maximum(cn2[:, None] + pn2[None, :] - 2.0 * dots, 0.0)
    d2 = jnp.where(cv[:, None], d2, jnp.float32(jnp.inf))
    mind2 = jnp.min(d2.reshape(qpad, cmax, BLOCK), axis=1)   # [Q, 128]

    mu = mu_ref[...][:, None]                           # [Q, 1]
    r = r_ref[...][:, None]
    ball_mode = mode_ref[...][:, None] == MODE_BALL
    finite = jnp.isfinite(mind2)
    # mind2^(mu/2) = d^mu via exp/log (Mosaic-safe power); d = 0 -> 0
    cost = jnp.where(mind2 > 0,
                     jnp.exp(0.5 * mu * jnp.log(jnp.maximum(mind2, 1e-38))),
                     0.0)
    ball = (mind2 <= r * r).astype(jnp.float32)
    fv = jnp.where(finite, jnp.where(ball_mode, ball, cost), 0.0)
    out_ref[...] += fv * ht[None, :]                    # per-lane partials


@partial(jax.jit, static_argnames=("interpret",))
def _service_cost_jit(points, probs, member, table, point_weights, interpret):
    interpret = resolve_interpret(interpret)
    c, dim = points.shape
    qn, cmax, cdim = table.centers.shape
    if cdim != dim:
        raise ValueError(f"center dim {cdim} != point dim {dim}")
    cpad = round_up(max(c, 1), BLOCK)
    qpad = round_up(qn, _SUBLANES)
    dpad = round_up(dim, _SUBLANES)

    pw = (jnp.ones((c,), jnp.float32) if point_weights is None
          else jnp.asarray(point_weights, jnp.float32))
    ht = jnp.where(jnp.asarray(member, bool),
                   pw / jnp.maximum(jnp.asarray(probs, jnp.float32), 1e-30),
                   0.0)
    pts = jnp.pad(jnp.asarray(points, jnp.float32),
                  ((0, cpad - c), (0, dpad - dim))).T          # [dpad, cpad]
    ht = pad_tail(ht, cpad, 0.0)
    ctr = jnp.pad(jnp.asarray(table.centers, jnp.float32),
                  ((0, qpad - qn), (0, 0), (0, dpad - dim)))
    ctr = ctr.reshape(qpad * cmax, dpad)
    cv = jnp.pad(jnp.asarray(table.cvalid, bool).astype(jnp.int32),
                 ((0, qpad - qn), (0, 0))).reshape(-1)
    mu = pad_tail(jnp.asarray(table.mu, jnp.float32), qpad, 0.0)
    r = pad_tail(jnp.asarray(table.param, jnp.float32), qpad, 0.0)
    mode = pad_tail(jnp.asarray(table.mode, jnp.int32), qpad, 0)

    out = pl.pallas_call(
        partial(_servicecost_kernel, qpad=qpad, cmax=cmax),
        grid=(cpad // BLOCK,),
        in_specs=[
            pl.BlockSpec((dpad, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((qpad * cmax, dpad), lambda i: (0, 0)),
            pl.BlockSpec((qpad * cmax,), lambda i: (0,)),
            pl.BlockSpec((qpad,), lambda i: (0,)),
            pl.BlockSpec((qpad,), lambda i: (0,)),
            pl.BlockSpec((qpad,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((qpad, BLOCK), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((qpad, BLOCK), jnp.float32),
        interpret=interpret,
    )(pts, ht, ctr, cv, mu, r, mode)
    return jnp.sum(out, axis=1)[:qn]


def service_cost_slab(points, probs, member, queries, point_weights=None,
                      interpret=None):
    """Batched service-cost estimates over one sampled slab -> [Q].

    points: slot coordinates [c, dim] aligned with probs/member (the
    MultiSketch slab fields); queries: ServiceCostQuery batch or encoded
    ``CostTable`` (core.costs). ONE pallas launch regardless of Q and Cmax;
    the grid runs only over slab blocks (c / 128 steps, accumulating the
    [Q, 128] partial sums in place).
    """
    table = encode_cost_queries(queries)
    return _service_cost_jit(
        jnp.asarray(points, jnp.float32), jnp.asarray(probs, jnp.float32),
        jnp.asarray(member, bool),
        CostTable(*(jnp.asarray(x) for x in table)),
        point_weights if point_weights is None
        else jnp.asarray(point_weights, jnp.float32),
        interpret)
