"""Fused segment-query kernel: B predicates x |F| objectives, ONE launch.

Serving answers many segment-sum queries Q^(f, H) against one resident
MultiSketch slab (paper §2-3: a single summary answers every f in F with
per-objective CV guarantees). Evaluated one (f, H) pair at a time, each
query pays a full launch + an O(c) pass over the slab; this kernel fuses a
whole query batch into one VMEM-resident launch:

  per slab block of c_b slots (ONE HBM read of keys/weights/probs/member):
    ht      [c_b]       member ? 1 / p^(F) : 0         (HT weight, Eq. 5)
    contrib [F, c_b]    f_j(w) * ht for every objective (objectives are
                        compile-time (kind, param) pairs, same encoding as
                        kernels.seeds)
    sel     [B, c_b]    the predicate wire table (core.predicates) applied
                        to the block's keys — range / bitmask / hashed-
                        fraction tests, hash computed in-kernel
    out    += contrib @ sel^T                           [F, B] accumulate

The objective axis rides the sublane dimension and the predicate batch the
lane dimension (the MXU/VPU-native layout, like blockselect's batched
rows), so launch count AND grid size are flat in both B and |F| — only the
O(c) slab-bandwidth term plus the O(F B) accumulator remain. B and |F| are
padded to tile multiples (128 / 8) and the result sliced back.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.predicates import FLAG_ON_HASH, PRED_COLS
from repro.kernels._util import pad_tail, resolve_interpret, round_up
from repro.kernels.seeds import _fval, _mix

BLOCK = 512       # slab slots per grid step
_LANES = 128      # predicate-batch padding quantum
_SUBLANES = 8     # objective-axis padding quantum
_GOLDEN = np.uint32(0x9E3779B9)


def _segquery_kernel(keys_ref, w_ref, p_ref, m_ref, pred_ref, out_ref, *,
                     objectives, nf_pad):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    k = keys_ref[...]                                   # [c_b] int32
    w = w_ref[...].astype(jnp.float32)
    prob = p_ref[...].astype(jnp.float32)
    member = m_ref[...] != 0

    # HT contributions, one row per objective (zero rows pad to nf_pad)
    ht = jnp.where(member, 1.0 / jnp.maximum(prob, 1e-30), 0.0)
    rows = [_fval(kind, param, w) * ht for kind, param in objectives]
    rows += [jnp.zeros_like(ht)] * (nf_pad - len(rows))
    contrib = jnp.stack(rows)                           # [nf_pad, c_b]

    # predicate selection — same semantics as core.predicates.predicate_matrix
    lo = pred_ref[0, :][:, None]                        # [B, 1]
    hi = pred_ref[1, :][:, None]
    mask = pred_ref[2, :][:, None]
    want = pred_ref[3, :][:, None]
    salt = pred_ref[4, :][:, None].astype(jnp.uint32)
    on_hash = (pred_ref[5, :][:, None] & FLAG_ON_HASH) != 0
    ku = k[None, :].astype(jnp.uint32)                  # [1, c_b]
    h = _mix(ku + _GOLDEN + salt)                       # [B, c_b]
    h = _mix(h ^ (salt * np.uint32(0x85EBCA6B) + np.uint32(1)))
    hv = (h >> np.uint32(1)).astype(jnp.int32)          # hash31, in [0, 2^31)
    v = jnp.where(on_hash, hv, k[None, :])
    sel = ((v >= lo) & (v <= hi) & ((v & mask) == want)
           & (k[None, :] >= 0)).astype(jnp.float32)     # [B, c_b]

    out_ref[...] += jax.lax.dot_general(
        contrib, sel, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # [nf_pad, B]


@partial(jax.jit, static_argnames=("objectives", "interpret"))
def segment_query_slab(keys, weights, probs, member, table, objectives,
                       interpret=None):
    """Batched segment queries over one slab: -> estimates [|F|, B].

    keys/weights/probs/member: the MultiSketch wire slab fields [c];
    table: int32 predicate wire table [B, PRED_COLS] (core.predicates);
    objectives: static tuple of (kind, param) pairs (kernels.seeds encoding).
    ONE pallas launch regardless of B and |F|; the grid runs only over slab
    blocks (c / BLOCK steps, accumulating the [F, B] output in place).
    """
    interpret = resolve_interpret(interpret)
    nf = len(objectives)
    b = table.shape[0]
    if table.shape[1] != PRED_COLS:
        raise ValueError(f"predicate table must be [B, {PRED_COLS}], "
                         f"got {table.shape}")
    c = keys.shape[0]
    cpad = round_up(max(c, 1), BLOCK)
    nf_pad = round_up(nf, _SUBLANES)
    bpad = round_up(b, _LANES)

    k = pad_tail(jnp.asarray(keys, jnp.int32), cpad, -1)
    w = pad_tail(jnp.asarray(weights, jnp.float32), cpad, 0.0)
    p = pad_tail(jnp.asarray(probs, jnp.float32), cpad, 0.0)
    m = pad_tail(jnp.asarray(member).astype(jnp.int32), cpad, 0)
    # predicates ride the lane axis: transpose the table to [PRED_COLS, Bpad]
    t = jnp.asarray(table, jnp.int32)
    t = jnp.pad(t, ((0, bpad - b), (0, 0))).T

    out = pl.pallas_call(
        partial(_segquery_kernel, objectives=tuple(objectives),
                nf_pad=nf_pad),
        grid=(cpad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((PRED_COLS, bpad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((nf_pad, bpad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((nf_pad, bpad), jnp.float32),
        interpret=interpret,
    )(k, w, p, m, t)
    return out[:nf, :b]
