"""Block-local bottom-k selection kernel (paper §2.2's core primitive).

Bottom-k sampling needs the k smallest f-seeds of n keys. Heaps don't map
to the VPU; the TPU-native plan is two-level selection:
  1. THIS KERNEL: per VMEM block, select the block's k smallest seeds with
     k unrolled min+mask rounds (pure vector ops, no data-dependent control
     flow), emitting [n/B, k] candidates + their indices;
  2. host/XLA: one top_k over the n/B * k << n candidates.

The k smallest of the union are always among the per-block k smallest, so
the two-level result is exact. One HBM read of the seeds, k*n/B vector
mins — bandwidth-optimal for k << B.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 2048
_INF = np.float32(np.inf)


def _blockselect_kernel(seeds_ref, vals_ref, idx_ref, *, k: int, block: int):
    i = pl.program_id(0)
    s = seeds_ref[...].astype(jnp.float32)
    base = i * block
    local_idx = jax.lax.iota(jnp.int32, block)
    for j in range(k):
        m = jnp.min(s)
        # first position attaining the min (iota tiebreak)
        is_min = s == m
        pos = jnp.min(jnp.where(is_min, local_idx, block))
        vals_ref[j] = m
        idx_ref[j] = jnp.where(jnp.isfinite(m), base + pos, -1)
        s = jnp.where(local_idx == pos, _INF, s)


@partial(jax.jit, static_argnames=("k", "interpret"))
def block_bottomk(seeds, k: int, interpret: bool = True):
    """seeds [n] -> (vals [nb, k], idx [nb, k]) block-local k smallest."""
    n = seeds.shape[0]
    b = min(BLOCK, n)
    assert n % b == 0
    nb = n // b
    return pl.pallas_call(
        partial(_blockselect_kernel, k=k, block=b),
        grid=(nb,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((k,), lambda i: (i,)),
                   pl.BlockSpec((k,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb * k,), jnp.float32),
                   jax.ShapeDtypeStruct((nb * k,), jnp.int32)],
        interpret=interpret,
    )(seeds.astype(jnp.float32))


@partial(jax.jit, static_argnames=("k", "interpret"))
def bottomk_select(seeds, k: int, interpret: bool = True):
    """Exact global bottom-k via block-local selection + candidate merge.

    Returns (vals [k] ascending, idx [k]; invalid slots = (+inf, -1)) and
    tau = the (k+1)-th smallest seed (+inf if fewer), matching
    core.bottomk semantics.
    """
    vals, idx = block_bottomk(seeds, min(k + 1, seeds.shape[0]),
                              interpret=interpret)
    neg_top, pos = jax.lax.top_k(-vals, min(k + 1, vals.shape[0]))
    cand_vals = -neg_top
    cand_idx = idx[pos]
    tau = cand_vals[k] if cand_vals.shape[0] > k else jnp.float32(jnp.inf)
    return cand_vals[:k], cand_idx[:k], tau
