"""Block-local bottom-k selection kernel (paper §2.2's core primitive).

Bottom-k sampling needs the k smallest f-seeds of n keys. Heaps don't map
to the VPU; the TPU-native plan is two-level selection:
  1. THIS KERNEL: per VMEM block, select the block's k smallest seeds with
     k unrolled min+mask rounds (pure vector ops, no data-dependent control
     flow), emitting [n/B, k] candidates + their indices;
  2. host/XLA: one top_k over the n/B * k << n candidates.

The k smallest of the union are always among the per-block k smallest, so
the two-level result is exact. One HBM read of the seeds, k*n/B vector
mins — bandwidth-optimal for k << B.

The kernel is natively BATCHED over objectives: it consumes the [|F|, n]
seed matrix of ``fused_seeds`` directly as (|F|, B) VMEM slabs — the
(|F|, n/B) block decomposition with the |F| axis vectorized into the VPU
sublane dimension (full occupancy at |F| >= 8) instead of serialized into
grid steps. A multi-objective sample therefore costs ONE launch whose
per-step work is the pure O(|F| B) bandwidth term, plus one top_k over
[|F|, nb*k] candidates — not |F| launches + 2|F| full-n scans. The 1D
entry points are views of the batched path with |F| = 1.

Ragged n is auto-padded with +inf seeds (idx -1), which never survive
selection ahead of a finite seed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels._util import pad_tail, resolve_interpret, round_up

BLOCK = 2048
_INF = np.float32(np.inf)


def _blockselect_kernel(seeds_ref, vals_ref, idx_ref, *, k: int, block: int):
    i = pl.program_id(0)  # block index along n
    s = seeds_ref[...].astype(jnp.float32)          # [F, block]
    base = i * block
    local_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    for j in range(k):
        m = jnp.min(s, axis=1, keepdims=True)       # [F, 1], all rows at once
        # first position attaining each row's min (iota tiebreak)
        is_min = s == m
        pos = jnp.min(jnp.where(is_min, local_idx, block), axis=1,
                      keepdims=True)
        vals_ref[:, j] = m[:, 0]
        idx_ref[:, j] = jnp.where(jnp.isfinite(m[:, 0]), base + pos[:, 0], -1)
        s = jnp.where(local_idx == pos, _INF, s)


@partial(jax.jit, static_argnames=("k", "interpret"))
def batched_block_bottomk(seeds, k: int, interpret=None):
    """seeds [F, n] -> (vals [F, nb*k], idx [F, nb*k]) block-local k smallest.

    One pallas launch for ALL objectives: grid (n/B,), each step selecting
    the k smallest of every objective row of a (F, B) slab simultaneously;
    n is padded to a block multiple with +inf seeds (idx -1).
    """
    interpret = resolve_interpret(interpret)
    nf, n = seeds.shape
    # lane-aligned block fit: delta-slab inputs (an incremental merge's
    # (1 + dirty) x capacity retained slots) are far below the streaming
    # BLOCK — round the block to the 128-lane quantum, not up to BLOCK
    b = min(BLOCK, round_up(n, 128))
    npad = round_up(n, b)
    s = pad_tail(seeds.astype(jnp.float32), npad, _INF)
    nb = npad // b
    vals, idx = pl.pallas_call(
        partial(_blockselect_kernel, k=k, block=b),
        grid=(nb,),
        in_specs=[pl.BlockSpec((nf, b), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((nf, k), lambda i: (0, i)),
                   pl.BlockSpec((nf, k), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((nf, nb * k), jnp.float32),
                   jax.ShapeDtypeStruct((nf, nb * k), jnp.int32)],
        interpret=interpret,
    )(s)
    return vals, idx


@partial(jax.jit, static_argnames=("k", "interpret"))
def batched_bottomk_select(seeds, k: int, interpret=None):
    """Exact global bottom-k per objective: one launch + one batched merge.

    seeds [F, n] -> (vals [F, k] ascending, idx [F, k]; invalid slots =
    (+inf, -1)) and tau [F] = the (k+1)-th smallest seed per objective
    (+inf if fewer), matching core.bottomk semantics row-wise.
    """
    nf, n = seeds.shape
    ksel = min(k + 1, n)
    vals, idx = batched_block_bottomk(seeds, ksel, interpret=interpret)
    m = min(k + 1, vals.shape[1])
    neg_top, pos = jax.lax.top_k(-vals, m)          # ONE scan for all F
    cand_vals = -neg_top
    cand_idx = jnp.take_along_axis(idx, pos, axis=1)
    tau = (cand_vals[:, k] if cand_vals.shape[1] > k
           else jnp.full((nf,), _INF, jnp.float32))
    return cand_vals[:, :k], cand_idx[:, :k], tau


def block_bottomk(seeds, k: int, interpret=None):
    """seeds [n] -> (vals [nb, k], idx [nb, k]) block-local k smallest."""
    vals, idx = batched_block_bottomk(seeds[None, :], k, interpret=interpret)
    return vals[0], idx[0]


def bottomk_select(seeds, k: int, interpret=None):
    """Exact global bottom-k via block-local selection + candidate merge.

    Returns (vals [k] ascending, idx [k]; invalid slots = (+inf, -1)) and
    tau = the (k+1)-th smallest seed (+inf if fewer), matching
    core.bottomk semantics.
    """
    vals, idx, tau = batched_bottomk_select(seeds[None, :], k,
                                            interpret=interpret)
    return vals[0], idx[0], tau[0]
