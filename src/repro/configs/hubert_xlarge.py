"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504,
encoder-only (w2v2 arch). Modality frontend (conv feature extractor) is a
STUB per spec: input_specs() provides precomputed frame embeddings.
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, mlp_kind="gelu", norm_kind="layernorm",
    causal=False, frontend="frames", loss_chunk=2048,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="encoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=32, mlp_kind="gelu", norm_kind="layernorm",
    causal=False, frontend="frames",
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
