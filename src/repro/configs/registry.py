"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ smoke config)."""
from __future__ import annotations

import importlib

ARCHS = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "gemma-2b": "gemma_2b",
    "deepseek-67b": "deepseek_67b",
    "hubert-xlarge": "hubert_xlarge",
    "internvl2-76b": "internvl2_76b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-2.7b": "zamba2_2_7b",
}


def _module(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE


def list_archs():
    return list(ARCHS)


def sub_quadratic(cfg) -> bool:
    """True if decode/long-context cost per token is sub-quadratic-safe
    (SSM / hybrid families; paper-spec gate for the long_500k shape)."""
    return cfg.family in ("ssm", "hybrid")
