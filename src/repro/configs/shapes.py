"""Assigned input-shape set (LM transformer shapes: seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len); the others lower ``train_step``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(arch_family: str, shape: ShapeConfig,
                     sub_quadratic: bool) -> tuple[bool, str]:
    """Spec-mandated skips. Returns (runnable, reason_if_not)."""
    if arch_family == "encoder" and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (spec skip)"
    return True, ""
