"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, mlp_kind="geglu", tie_embeddings=True,
    loss_chunk=256,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=256, mlp_kind="geglu", tie_embeddings=True,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
