"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, num_experts=32, moe_top_k=8,
    num_shared_experts=0, mlp_kind="swiglu", tie_embeddings=True,
    loss_chunk=1024,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=128, num_experts=4, moe_top_k=2,
    num_shared_experts=0, mlp_kind="swiglu", tie_embeddings=True,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
