"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-arch. [arXiv:2401.02954; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400, mlp_kind="swiglu", loss_chunk=512,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", family="dense",
    num_layers=3, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=128, mlp_kind="swiglu",
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
