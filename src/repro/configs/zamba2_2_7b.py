"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64, Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_kind="mamba2", ssm_state=64, ssm_conv=4, ssm_expand=2,
    ssm_head_dim=64, ssm_chunk=256, attn_every=6, loss_chunk=1024,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=128,
    ssm_kind="mamba2", ssm_state=8, ssm_conv=4, ssm_expand=2,
    ssm_head_dim=16, ssm_chunk=8, attn_every=2,
    attn_chunk=16, loss_chunk=16,
)
