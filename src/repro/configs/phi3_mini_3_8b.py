"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064, RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064, mlp_kind="swiglu", loss_chunk=1024,
)

SMOKE = ModelConfig(
    name="phi3-mini-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=128, mlp_kind="swiglu",
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
