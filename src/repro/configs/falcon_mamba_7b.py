"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16, mamba1 arch. [arXiv:2410.05355; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, d_ff=0, vocab_size=65024,
    ssm_kind="mamba1", ssm_state=16, ssm_conv=4, ssm_expand=2,
    ssm_chunk=256, loss_chunk=1024, fsdp=True,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke", family="ssm",
    num_layers=2, d_model=64, d_ff=0, vocab_size=128,
    ssm_kind="mamba1", ssm_state=8, ssm_conv=4, ssm_expand=2,
    ssm_chunk=8, attn_chunk=16, loss_chunk=16,
)
