"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, GQA + QKV bias. [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, mlp_kind="swiglu", qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6, loss_chunk=512,
)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, mlp_kind="swiglu", qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
