"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 + 4 shared. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936, num_experts=60, moe_top_k=4,
    num_shared_experts=4, mlp_kind="swiglu", qkv_bias=True,
    loss_chunk=512,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=128, num_experts=6, moe_top_k=2,
    num_shared_experts=2, mlp_kind="swiglu", qkv_bias=True,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
