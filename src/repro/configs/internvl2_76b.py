"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 (InternViT + InternLM2 backbone). Per spec the ViT frontend is a
STUB: input_specs() provides precomputed patch embeddings; we model the
LLM backbone over [patches | text]. [arXiv:2404.16821; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, mlp_kind="swiglu", rope_theta=1e6,
    frontend="patch", frontend_tokens=256, loss_chunk=512,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, mlp_kind="swiglu", rope_theta=1e6,
    frontend="patch", frontend_tokens=8,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
