"""Streaming training telemetry via mergeable multi-objective summaries.

Any stream of (key, weight) pairs produced during training or serving —
per-token losses, per-example grad norms, router loads, request sizes — is
folded into a fixed-capacity ``MultiSketch`` (core.multi_sketch). The fold
is a single jit-compiled device function with donated state buffers: no
per-batch Python rebuild, no host round-trip, no steady-state allocation.
Sketches merge exactly across steps (streaming), across collectors and
across hosts (``all_gather`` of the fixed-size slabs + one re-selection),
after which any f-statistic over any key segment is one HT sum away:
"how many tokens had loss >= 5?", "total loss mass in domain d?" — all
from one resident sketch, long after the raw stream is gone.

``StatsCollector`` is the thin host wrapper: it buckets ragged batch sizes
(to bound jit retraces), owns the device-resident state, and routes queries
through the batched segment-query path (``multisketch_estimate_batch`` —
one fused launch for any number of objectives x predicates; repeated
queries reuse one compiled executable per (spec, objectives, B-bucket)).
Arbitrary-callable ``segment_fn`` queries keep the eager
``sketch_estimate`` path (no per-callable compile cache).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (COUNT, SUM, MultiSketch, MultiSketchSpec,
                        multisketch_absorb, multisketch_empty,
                        multisketch_merge, multisketch_overflow,
                        multisketch_query_many, multisketch_slab_bytes,
                        sketch_estimate)
from repro.core.multi_sketch import pad_chunk
from repro.core.funcs import StatFn
from repro.core.predicates import EVERYTHING, SegmentPredicate


@dataclasses.dataclass
class TelemetryConfig:
    k: int = 64          # per-objective sample size for default objectives
    capacity: int = 1024
    seed: int = 1234
    scheme: str = "ppswor"
    # objectives default to ((SUM, k), (COUNT, k)): mass + support queries
    objectives: Tuple[Tuple[StatFn, int], ...] = ()
    chunk: int = 256     # absorb pad quantum (bounds jit retraces)

    def spec(self) -> MultiSketchSpec:
        objs = self.objectives or ((SUM, self.k), (COUNT, self.k))
        return MultiSketchSpec(objectives=objs, scheme=self.scheme,
                               seed=self.seed, capacity=self.capacity)


class StatsCollector:
    """Host handle on a device-resident mergeable multi-objective sample.

    ``absorb(keys, weights)`` folds a batch of keyed observations into the
    donated device state; ``query(f, segment_fn)`` estimates Q(f, H). Keys
    must be globally unique per observation (e.g. step * batch + position,
    staying within int32) — shared hashing makes the same key land
    identically on every host (coordination, paper §1), so cross-host
    merges stay exact. A key REPEATED across absorbs is instead treated as
    the same element re-observed and keeps its max weight.
    """

    def __init__(self, cfg: TelemetryConfig):
        self.cfg = cfg
        self.spec = cfg.spec()
        self.state: MultiSketch = multisketch_empty(self.spec)
        self._overflow_warned = False

    # -- streaming fold ----------------------------------------------------
    def absorb(self, keys, weights):
        keys, weights, active = pad_chunk(keys, weights,
                                          chunk=self.cfg.chunk)
        self.state = multisketch_absorb(self.state, keys, weights, active,
                                        spec=self.spec)

    def merge_from(self, other: "StatsCollector"):
        assert other.spec == self.spec, "collectors must share a spec"
        self.state = multisketch_merge(self.spec, self.state, other.state)

    # -- queries -----------------------------------------------------------
    def query(self, f: StatFn, segment_fn=None) -> float:
        """Estimate Q(f, H); segment_fn: a ``SegmentPredicate`` (preferred)
        or any vectorized key callable.

        Predicate (and whole-set) queries route through the batched
        single-launch path and reuse one compiled executable per
        (spec, f, B-bucket) — repeated queries are O(1) launches.
        Callable segments keep the eager ``sketch_estimate`` path (no
        per-callable compile cache); express hot segments as
        ``SegmentPredicate`` rows to get the fused path.
        """
        if segment_fn is None or isinstance(segment_fn, SegmentPredicate):
            pred = EVERYTHING if segment_fn is None else segment_fn
            return float(self.query_many((f,), (pred,))[0, 0])
        return float(sketch_estimate(self.state, f, segment_fn))

    def query_many(self, fs: Sequence[StatFn],
                   predicates=(EVERYTHING,)) -> np.ndarray:
        """Q(f_i, H_b) for a whole query batch -> float [|F|, B]: ONE fused
        launch over the resident slab (kernels.segquery), B bucketed to
        bound retraces."""
        self._warn_if_overflowed()
        return multisketch_query_many(self.state, fs, predicates)

    @property
    def overflow(self) -> bool:
        """True iff the pool saturated — compaction may have truncated
        S ∪ Z, silently degrading cv below the Thm 3.1 guarantee."""
        return bool(multisketch_overflow(self.state))

    def _warn_if_overflowed(self):
        # checked at query time (one cheap device read per query batch,
        # not one per absorb on the hot fold path); warns ONCE per
        # collector — a saturated sketch used to degrade with no signal
        if not self._overflow_warned and self.overflow:
            self._overflow_warned = True
            warnings.warn(
                f"StatsCollector pool overflowed (capacity "
                f"{self.spec.cap}): S ∪ Z may be truncated and estimate "
                f"cv is no longer guaranteed — raise TelemetryConfig."
                f"capacity or lower the per-objective k",
                RuntimeWarning, stacklevel=3)

    def size(self) -> int:
        return int(jnp.sum(self.state.member))

    def stats(self) -> dict:
        """Resident-footprint gauges under the serving tier's
        ``merge_stats`` wire names, so collector telemetry can be
        exported next to `EnginePool` stream stats: the collector is a
        single always-compacted slab, so bytes are a spec constant and
        live_shards is 1 by construction."""
        return {
            "bytes_resident": multisketch_slab_bytes(self.spec),
            "live_shards": 1,
            "gc_merges": 0,
            "live_keys": self.size(),
            "multisketch_overflow": self.overflow,
        }

    @property
    def sketch(self) -> MultiSketch:
        """The wire-format state (e.g. for all_gather / checkpointing)."""
        return self.state


def collect_host_gauges(pool) -> dict:
    """Scale-out telemetry rows for a ``launch.pool.ShardedEnginePool``:
    per-host residency/health gauges under the same ``merge_stats`` wire
    names as ``StatsCollector.stats`` and the stream stats (so one export
    pipeline carries collector, stream and host rows), plus group totals.

    Returns ``{"hosts": {host_id: row}, "totals": row}`` where each row
    carries ``live_shards`` / ``bytes_resident`` / ``gc_merges`` summed
    over the host's resident engines and the scale-out extras (``alive``,
    ``owned_shards``, ``replica_streams``). Totals count LIVE hosts only —
    a dead host's residency is gone, and exporting it would overstate the
    group's footprint. Host-side gauges throughout: no device sync."""
    hosts = pool.host_stats()
    totals = {"hosts": len(hosts),
              "hosts_alive": sum(1 for r in hosts.values() if r["alive"]),
              "live_shards": 0, "bytes_resident": 0, "gc_merges": 0,
              "owned_shards": 0, "replica_streams": 0}
    for row in hosts.values():
        if not row["alive"]:
            continue
        for k in ("live_shards", "bytes_resident", "gc_merges",
                  "owned_shards", "replica_streams"):
            totals[k] += row[k]
    return {"hosts": hosts, "totals": totals}
