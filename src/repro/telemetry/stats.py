"""Streaming training telemetry via mergeable universal samples.

Any stream of (key, weight) pairs produced during training — per-token
losses, per-example grad norms, router loads, activation magnitudes — is
absorbed into a fixed-size universal monotone sketch (core.merge.Sketch).
Sketches merge across steps (streaming) and across hosts (all_gather of the
fixed-size arrays), after which ANY monotone f-statistic over ANY key
segment can be estimated with gold-standard CV (paper Thm 5.1/§5.1):
"how many tokens had loss >= 5?", "what is the total loss mass in domain
d?", "capped-at-T contribution of the worst examples?" — all from one
sketch, long after the raw stream is gone.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Sketch, build_sketch, estimate, merge_sketches,
                        sketch_capacity, universal_monotone_sample)
from repro.core.funcs import StatFn


@dataclasses.dataclass
class TelemetryConfig:
    k: int = 64
    capacity: int = 1024
    seed: int = 1234


class StatsCollector:
    """Host-side accumulator of a mergeable universal sample.

    ``absorb(keys, weights)`` folds a new batch of keyed observations in;
    ``query(f, segment_fn)`` estimates Q(f, H). Keys must be globally unique
    per observation (e.g. step << 32 | position) — shared hashing makes the
    same key land identically on every host (coordination, paper §1).
    """

    def __init__(self, cfg: TelemetryConfig):
        self.cfg = cfg
        self.sketch: Sketch | None = None

    def absorb(self, keys, weights):
        keys = jnp.asarray(keys, jnp.int32).reshape(-1)
        weights = jnp.asarray(weights, jnp.float32).reshape(-1)
        active = weights > 0
        new = build_sketch(keys, weights, active, self.cfg.k,
                           self.cfg.capacity, seed=self.cfg.seed)
        self.sketch = (new if self.sketch is None
                       else merge_sketches(self.sketch, new))

    def merge_from(self, other: "StatsCollector"):
        if other.sketch is not None:
            self.sketch = (other.sketch if self.sketch is None
                           else merge_sketches(self.sketch, other.sketch))

    def query(self, f: StatFn, segment_fn=None) -> float:
        """Estimate Q(f, H); segment_fn: vectorized predicate over keys."""
        if self.sketch is None:
            return 0.0
        sk = self.sketch
        member = sk.member
        if segment_fn is not None:
            member = member & jnp.asarray(segment_fn(sk.keys), bool)
        contrib = jnp.where(member,
                            f(sk.weights) / jnp.maximum(sk.probs, 1e-30), 0.0)
        return float(jnp.sum(contrib))

    def size(self) -> int:
        return 0 if self.sketch is None else int(self.sketch.member.sum())
