"""Streaming training telemetry via mergeable multi-objective summaries.

Any stream of (key, weight) pairs produced during training or serving —
per-token losses, per-example grad norms, router loads, request sizes — is
folded into a fixed-capacity ``MultiSketch`` (core.multi_sketch). The fold
is a single jit-compiled device function with donated state buffers: no
per-batch Python rebuild, no host round-trip, no steady-state allocation.
Sketches merge exactly across steps (streaming), across collectors and
across hosts (``all_gather`` of the fixed-size slabs + one re-selection),
after which any f-statistic over any key segment is one HT sum away:
"how many tokens had loss >= 5?", "total loss mass in domain d?" — all
from one resident sketch, long after the raw stream is gone.

``StatsCollector`` is the thin host wrapper: it buckets ragged batch sizes
(to bound jit retraces), owns the device-resident state, and routes queries
through ``core.merge.sketch_estimate``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (COUNT, SUM, MultiSketch, MultiSketchSpec,
                        multisketch_absorb, multisketch_empty,
                        multisketch_merge, sketch_estimate)
from repro.core.funcs import StatFn


@dataclasses.dataclass
class TelemetryConfig:
    k: int = 64          # per-objective sample size for default objectives
    capacity: int = 1024
    seed: int = 1234
    scheme: str = "ppswor"
    # objectives default to ((SUM, k), (COUNT, k)): mass + support queries
    objectives: Tuple[Tuple[StatFn, int], ...] = ()
    chunk: int = 256     # absorb pad quantum (bounds jit retraces)

    def spec(self) -> MultiSketchSpec:
        objs = self.objectives or ((SUM, self.k), (COUNT, self.k))
        return MultiSketchSpec(objectives=objs, scheme=self.scheme,
                               seed=self.seed, capacity=self.capacity)


class StatsCollector:
    """Host handle on a device-resident mergeable multi-objective sample.

    ``absorb(keys, weights)`` folds a batch of keyed observations into the
    donated device state; ``query(f, segment_fn)`` estimates Q(f, H). Keys
    must be globally unique per observation (e.g. step * batch + position,
    staying within int32) — shared hashing makes the same key land
    identically on every host (coordination, paper §1), so cross-host
    merges stay exact. A key REPEATED across absorbs is instead treated as
    the same element re-observed and keeps its max weight.
    """

    def __init__(self, cfg: TelemetryConfig):
        self.cfg = cfg
        self.spec = cfg.spec()
        self.state: MultiSketch = multisketch_empty(self.spec)

    # -- streaming fold ----------------------------------------------------
    def absorb(self, keys, weights):
        keys = np.asarray(keys, np.int32).reshape(-1)
        weights = np.asarray(weights, np.float32).reshape(-1)
        active = weights > 0
        n = keys.shape[0]
        npad = max(self.cfg.chunk, -(-n // self.cfg.chunk) * self.cfg.chunk)
        if npad > n:  # pad to the chunk quantum so jit traces stay bounded
            keys = np.pad(keys, (0, npad - n), constant_values=-1)
            weights = np.pad(weights, (0, npad - n))
            active = np.pad(active, (0, npad - n))
        self.state = multisketch_absorb(self.state, keys, weights, active,
                                        spec=self.spec)

    def merge_from(self, other: "StatsCollector"):
        assert other.spec == self.spec, "collectors must share a spec"
        self.state = multisketch_merge(self.spec, self.state, other.state)

    # -- queries -----------------------------------------------------------
    def query(self, f: StatFn, segment_fn=None) -> float:
        """Estimate Q(f, H); segment_fn: vectorized predicate over keys."""
        return float(sketch_estimate(self.state, f, segment_fn))

    def size(self) -> int:
        return int(jnp.sum(self.state.member))

    @property
    def sketch(self) -> MultiSketch:
        """The wire-format state (e.g. for all_gather / checkpointing)."""
        return self.state
