"""Fault-tolerant checkpointing.

Design (per DESIGN.md §6):
  * mesh-agnostic: leaves are gathered to host and stored dense, so a job
    restarted on a DIFFERENT mesh (elastic re-scale, pod loss) re-shards on
    load via the new mesh's shardings;
  * atomic: write to step_N.tmp/, fsync EVERY file (arrays and meta.json)
    plus the directories, os.replace -> step_N/ — a crash at any point,
    including right after the rename, never persists a checkpoint whose
    arrays did not hit disk;
  * integrity: per-array crc32 stored in meta.json and verified on restore;
    a corrupt checkpoint is skipped and the previous one restored;
  * keep-last-k pruning + optional async (background thread) saves, with a
    manager-wide lock so an async save/prune can never race a concurrent
    restore reading a step directory mid-delete.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread = None
        # serializes write/prune against restore reads (RLock: _write
        # calls _prune while holding it) — an async save can otherwise
        # delete a step directory out from under a concurrent restore
        self._lock = threading.RLock()

    # ------------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = True,
             extra_meta: dict | None = None):
        """Gather to host and persist. With blocking=False the serialization
        happens on a background thread (training continues). ``extra_meta``:
        JSON-able dict stored under meta.json["extra"] — static context a
        restoring job needs before it can build a template (e.g. a
        MultiSketchSpec encoding, see core.multi_sketch.spec_to_meta)."""
        self.wait()  # never two writers at once (same-step races included)
        if step in self.list_steps():
            return
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(state).items()}
        if blocking:
            self._write(step, host, extra_meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra_meta),
                daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def _fsync_dir(path: str):
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _write(self, step: int, host: dict, extra_meta: dict | None = None):
        with self._lock:
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            meta = {"step": step, "arrays": {}, "extra": extra_meta or {}}
            for k, v in host.items():
                fn = k.replace(_SEP, "__") + ".npy"
                # fsync each array file: the rename below only orders the
                # DIRECTORY entry — without these fsyncs a crash after
                # os.replace can persist a checkpoint whose array bytes
                # never hit disk (meta.json alone was never enough)
                with open(os.path.join(tmp, fn), "wb") as f:
                    np.save(f, v)
                    f.flush()
                    os.fsync(f.fileno())
                meta["arrays"][k] = {
                    "file": fn, "crc": zlib.crc32(v.tobytes()) & 0xFFFFFFFF,
                    "shape": list(v.shape), "dtype": str(v.dtype)}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            self._fsync_dir(tmp)       # file entries durable before rename
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._fsync_dir(self.dir)  # the rename itself durable
            self._prune()

    def _prune(self):
        with self._lock:
            steps = self.list_steps()
            for s in steps[:-self.keep]:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                              ignore_errors=True)

    # ---------------------------------------------------------- restore
    def list_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def read_meta(self, step: int | None = None):
        """(step, meta dict) of the given — else the newest readable —
        checkpoint, without loading arrays. The restore entry point for
        jobs that must reconstruct their state TEMPLATE from the stored
        ``extra`` metadata first (e.g. SegmentQueryEngine.from_checkpoint).
        Raises FileNotFoundError when no checkpoint is readable."""
        steps = [step] if step is not None else reversed(self.list_steps())
        for s in steps:
            try:
                with self._lock, \
                        open(os.path.join(self.dir, f"step_{s:010d}",
                                          "meta.json")) as f:
                    return s, json.load(f)
            except (OSError, ValueError):   # missing OR corrupt json
                continue
        raise FileNotFoundError(f"no readable checkpoint under {self.dir}")

    def _load(self, step: int):
        with self._lock:   # a concurrent save's prune must not delete the
            d = os.path.join(self.dir, f"step_{step:010d}")  # dir mid-read
            with open(os.path.join(d, "meta.json")) as f:
                meta = json.load(f)
            arrays = {}
            for k, info in meta["arrays"].items():
                v = np.load(os.path.join(d, info["file"]))
                if (zlib.crc32(v.tobytes()) & 0xFFFFFFFF) != info["crc"]:
                    raise IOError(f"checksum mismatch for {k} at step {step}")
                arrays[k] = v
            return meta["step"], arrays

    def restore_step(self, step: int, template, shardings=None):
        """Restore ONE specific step into ``template``'s structure, or None
        if that step is corrupt/partial. Lets a caller that derives the
        template from the step's own metadata (read_meta) keep meta and
        arrays from the SAME checkpoint while falling back step by step."""
        try:
            step, arrays = self._load(step)
        except Exception as e:  # corrupt -> caller tries previous
            print(f"[ckpt] skipping step {step}: {e}")
            return None
        keys = _flatten(template)
        missing = set(keys) - set(arrays)
        if missing:
            print(f"[ckpt] step {step} missing {len(missing)} arrays")
            return None
        shard_map_ = _flatten(shardings) if shardings is not None else {}
        flat, treedef = jax.tree_util.tree_flatten(template)
        vals = []
        for k, tpl in keys.items():
            arr = arrays[k]
            sh = shard_map_.get(k)
            if sh is not None:
                vals.append(jax.device_put(arr, sh))
            else:
                vals.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, vals)

    def restore_latest(self, template, shardings=None):
        """Restore the newest intact checkpoint into ``template``'s structure.
        Corrupt/partial checkpoints are skipped (fault tolerance). Returns
        (state, step) or (None, -1)."""
        for step in reversed(self.list_steps()):
            state = self.restore_step(step, template, shardings)
            if state is not None:
                return state, step
        return None, -1
