"""Logical-axis -> mesh-axis mapping (partition rules).

Model code declares per-dimension LOGICAL axes ("embed", "q_heads", "mlp",
"vocab", "expert", "inner", ...). This module maps them to physical mesh axes
with divisibility gating: a dimension is sharded on "model" only when its
size divides evenly — otherwise it is replicated (recorded for the roofline
notes; XLA padding of uneven shards is avoided by construction).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axes that map to the tensor-parallel ("model") mesh axis
_MODEL_AXES = ("q_heads", "kv_heads", "mlp", "vocab", "expert", "inner")


def _is_spec(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                        for a in x)


def map_spec_tree(fn, spec_tree):
    return jax.tree.map(fn, spec_tree, is_leaf=_is_spec)


def logical_to_pspec(spec: tuple, shape: tuple, mesh, fsdp: bool = False) -> P:
    """One param's logical spec + shape -> PartitionSpec on this mesh.

    fsdp=True additionally shards the largest remaining divisible dim over
    "data" (ZeRO-3 semantics: GSPMD inserts the per-layer all-gathers).
    """
    msize = mesh.shape["model"]
    axes = []
    used = False  # at most one dim per mesh axis; first eligible wins
    for dim, name in zip(shape, spec):
        if not used and name in _MODEL_AXES and dim % msize == 0:
            axes.append("model")
            used = True
        else:
            axes.append(None)
    if fsdp and "data" in mesh.axis_names:
        dsize = mesh.shape["data"]
        named = list(spec) + [None] * (len(shape) - len(spec))
        # only NAMED dims are fsdp-eligible: the anonymous leading dim of
        # stacked layer params is scanned over and must stay unsharded
        cand = sorted(((d, i) for i, d in enumerate(shape)
                       if axes[i] is None and named[i] is not None
                       and d % dsize == 0 and d >= dsize),
                      reverse=True)
        if cand:
            axes[cand[0][1]] = "data"
    # strip trailing Nones for tidiness
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


def param_shardings(spec_tree, shape_tree, mesh, fsdp: bool = False):
    """NamedSharding tree for params (and, reused, optimizer moments)."""
    def one(spec, shaped):
        return NamedSharding(mesh, logical_to_pspec(tuple(spec), shaped.shape,
                                                    mesh, fsdp))
    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=_is_spec)


def batch_pspec(mesh) -> P:
    """Global-batch sharding over (pod?, data)."""
    if "pod" in mesh.axis_names:
        return P(("pod", "data"))
    return P("data")


def _nshards(mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def batch_shardings(batch_tree, mesh):
    """Shard every batch leaf on its leading (batch) dimension; replicate
    when the batch does not divide the data axes (e.g. long_500k B=1)."""
    baxis = batch_pspec(mesh)[0]
    n = _nshards(mesh, baxis)

    def one(leaf):
        extra = max(leaf.ndim - 1, 0)
        lead = baxis if leaf.shape[0] % n == 0 else None
        return NamedSharding(mesh, P(*([lead] + [None] * extra)))
    return jax.tree.map(one, batch_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())


def cache_shardings(cache_tree, cfg, mesh):
    """Decode caches: batch dim on (pod?,data); head/channel dims on model
    where divisible. Cache layouts:
      dense kv:   [L, B, S, K, hd]   -> (None, batch, None, model?, None)
      hybrid kv:  [G, B, S, K, hd]   -> same
      mamba conv: [L, B, K-1, C]     -> (None, batch, None, model?)
      mamba h:    [L, B, di, N] / [L, B, H, hd, N]
    """
    b = batch_pspec(mesh)[0]
    nb = _nshards(mesh, b)
    msize = mesh.shape["model"]

    def one(leaf):
        dims = list(leaf.shape)
        axes = [None] * leaf.ndim
        if leaf.ndim >= 2 and dims[1] % nb == 0:
            axes[1] = b  # batch is dim 1 (stacked layers lead)
        # shard the LARGEST DIVISIBLE remaining dim on model (seq for kv
        # caches -> sequence-parallel decode attention; channels for ssm)
        cand = sorted(((d, i) for i, d in enumerate(dims[2:], start=2)),
                      reverse=True)
        for d, i in cand:
            if d % msize == 0 and d >= msize:
                axes[i] = "model"
                break
        while axes and axes[-1] is None:
            axes.pop()
        return NamedSharding(mesh, P(*axes))
    return jax.tree.map(one, cache_tree)
