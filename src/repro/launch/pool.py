"""Fault-tolerant multi-tenant serving tier: the EnginePool.

Many named MultiSketch streams (tenants) behind ONE admission loop, each
stream a resident ``SegmentQueryEngine`` wrapped in the failure machinery
a million-user deployment needs. The design premise is the paper's:
coordinated mergeable sketches make degraded-but-correct answers POSSIBLE
— a stale merged slab is still an unbiased HT estimator with a known
(slightly worse) cv — and the fixed-capacity wire format makes
recovery-by-merge exact. So the pool promises "never wrong, occasionally
stale" instead of "occasionally down":

  * ADMISSION & BACKPRESSURE — a bounded request queue; ``submit`` raises
    :class:`RejectedError` when it is full (load shedding, never unbounded
    memory). ``pump`` drains the queue and COALESCES same-(stream,
    objectives) requests into one fused B-bucket launch (the
    ``multisketch_query_many`` quantum machinery), so burst traffic pays
    one kernel launch per bucket, not one per request. Per-request
    deadlines: a request already past its deadline at service time is
    answered ``REJECTED`` (error "deadline"), never silently late.
  * RETRY / TIMEOUT / BACKOFF — transient absorb/query failures (injected
    device errors, donation races) are retried with exponential backoff +
    jitter; persistent failure trips a per-stream circuit breaker.
  * GRACEFUL DEGRADATION LADDER — ``FRESH`` -> ``STALE(epoch_lag)`` ->
    ``REJECTED``. A stream whose breaker is open (or whose fresh query
    path fails after retries) serves from its LAST-GOOD merged slab; a
    failed delta fold leaves data durable in the WAL and downgrades
    responses to ``STALE`` with the exact chunk lag. Every response
    carries its staleness level and the ``multisketch_overflow`` flag —
    degraded answers are still unbiased estimates, and they are LABELED.
  * INPUT QUARANTINE — NaN/inf/negative rows are rejected PER ROW at
    absorb (``core.multi_sketch.quarantine_chunk``) with a per-stream
    counter: one bad producer cannot poison a tenant's slab.
  * DURABILITY — per-stream WAL of absorbed chunks (``launch.wal``,
    fsync'd write-ahead of the fold) + periodic ``CheckpointManager``
    snapshots. Crash recovery = restore newest intact snapshot -> replay
    the WAL tail -> lazy merge, BIT-IDENTICAL to the uncrashed engine
    (asserted in tests/test_serving_faults.py).
  * ADMIN OPS — ``request_gc``/``gc``/``compact`` ride a separate admin
    queue on the same admission loop: each ``pump`` serves EVERY pending
    query first, then at most ONE admin op (GC never starves reads), with
    the same deadline semantics. A GC drains the stream's fold backlog,
    applies the engine's shard GC (``gc_plan``/``gc_apply``), then
    appends a WAL GC marker (``wal.GC_SHARD``) carrying the victim list —
    apply-then-append, so recovery replays the recorded decision and
    lands in the identical post-GC shard layout. Responses served while
    the engine's newest epoch is a GC epoch are labeled ``gc_epoch``.

Fault-injection hooks: every failure-prone operation funnels through a
named fault point (``_fault_point``); the chaos harness (tests/faults.py)
installs deterministic failure schedules there without monkeypatching
library internals. Production runs have zero hooks installed and pay one
dict lookup per operation.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import threading
import time
from collections import deque
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.funcs import StatFn
from repro.core.multi_sketch import (MultiSketchSpec, multisketch_overflow,
                                     multisketch_query_many,
                                     quarantine_chunk, spec_from_meta,
                                     spec_to_meta)
from repro.core.predicates import EVERYTHING, encode_predicates
from repro.launch.query import SegmentQueryEngine
from repro.launch.wal import GC_SHARD, WriteAheadLog

# degradation-ladder response statuses (the serving contract, core.merge)
FRESH = "FRESH"
STALE = "STALE"
REJECTED = "REJECTED"


class RejectedError(RuntimeError):
    """Load shed: admission queue full / absorb backlog over its bound."""


class TransientFault(RuntimeError):
    """A retryable failure (injected device error, donation race)."""


# -- fault-injection points (chaos harness contract) ------------------------
# name -> hook(stream_name); an installed hook RAISES to inject a fault.
_FAULT_HOOKS: Dict[str, Callable[[str], None]] = {}

FAULT_POINTS = ("absorb_fold", "query_merge", "wal_append", "wal_replay",
                "ckpt_save", "ckpt_restore")


def install_fault_hook(point: str, fn: Callable[[str], None]):
    if point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}")
    _FAULT_HOOKS[point] = fn


def clear_fault_hooks():
    _FAULT_HOOKS.clear()


def _fault_point(point: str, stream: str):
    fn = _FAULT_HOOKS.get(point)
    if fn is not None:
        fn(stream)


# -- responses ---------------------------------------------------------------

@dataclasses.dataclass
class Response:
    """One answered query. ``values`` is float [|F|, B] (None iff
    REJECTED); ``epoch_lag`` counts accepted-but-unreflected absorb chunks
    (0 iff the answer covers every ack'd chunk); ``overflow`` mirrors
    ``multisketch_overflow`` of the slab that produced the answer."""

    status: str
    values: Optional[np.ndarray] = None
    epoch_lag: int = 0
    overflow: bool = False
    error: Optional[str] = None
    # the served slab's newest epoch was produced by a shard-GC merge
    # (same union, compacted layout) — labeled, like staleness
    gc_epoch: bool = False
    # admin-op (gc/compact) responses only: victim shards merged
    gc_victims: Optional[Tuple[int, ...]] = None

    @property
    def ok(self) -> bool:
        return self.status != REJECTED


@dataclasses.dataclass
class AbsorbReceipt:
    """Ack for one absorb: rows accepted (durable once ``durable``),
    rows quarantined, and whether the device fold already applied."""

    accepted: int
    quarantined: int
    applied: bool
    durable: bool
    seq: int = 0


class PoolFuture:
    """Completion handle for a submitted query."""

    def __init__(self):
        self._event = threading.Event()
        self._response: Optional[Response] = None

    def _set(self, response: Response):
        self._response = response
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Response:
        if not self._event.wait(timeout):
            raise TimeoutError("query not served within timeout")
        return self._response


@dataclasses.dataclass
class _Request:
    stream: str
    fs: Tuple[StatFn, ...]
    table: np.ndarray           # encoded predicate rows [b, PRED_COLS]
    deadline: Optional[float]
    future: PoolFuture


@dataclasses.dataclass
class _GcRequest:
    stream: str
    max_live: Optional[int]
    min_age: Optional[int]
    deadline: Optional[float]
    future: PoolFuture


class CircuitBreaker:
    """Consecutive-failure breaker: closed -> open after ``threshold``
    failures; open admits one half-open probe after ``reset_after``
    seconds; a probe success closes it, a probe failure re-opens."""

    def __init__(self, threshold: int = 3, reset_after: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = int(threshold)
        self.reset_after = float(reset_after)
        self._clock = clock
        self._failures = 0
        self._opened_at: Optional[float] = None
        self.open_count = 0     # times the breaker tripped (health metric)

    @property
    def is_open(self) -> bool:
        return self._opened_at is not None

    def allow(self) -> bool:
        """May the protected operation be ATTEMPTED now? True when closed,
        or when open long enough for a half-open probe."""
        if self._opened_at is None:
            return True
        return self._clock() - self._opened_at >= self.reset_after

    def record_success(self):
        self._failures = 0
        self._opened_at = None

    def record_failure(self):
        self._failures += 1
        if self._failures >= self.threshold:
            if self._opened_at is None:
                self.open_count += 1
            self._opened_at = self._clock()


class _Stream:
    """One tenant: engine + breaker + WAL + staleness bookkeeping."""

    def __init__(self, name: str, engine: SegmentQueryEngine,
                 breaker: CircuitBreaker, wal: Optional[WriteAheadLog],
                 ckpt_dir: Optional[str]):
        self.name = name
        self.engine = engine
        self.breaker = breaker
        self.wal = wal
        self.ckpt_dir = ckpt_dir
        self.ingest_seq = 0       # chunks accepted (and WAL'd, if durable)
        self.applied_seq = 0      # chunks folded into the engine
        self.quarantined = 0      # malformed rows rejected per-row
        self.snapshot_failures = 0
        self.folds_since_snapshot = 0
        self.snapshot_seqs: list = []      # applied_seq at each snapshot
        # (applied_seq_at_capture, merged slab) — the degraded-read replica
        self.last_good = None
        # fold backlog: chunks ack'd (durable) but not yet applied —
        # bounded; the WAL holds them too, this just avoids re-reading it
        self.pending = deque()


class EnginePool:
    """Multi-tenant serving pool. See module docstring for the contract.

    ``pump`` is the admission loop body: call it from your serving loop
    (deterministic — what the tests and the chaos bench do) or let
    ``start()`` run it on a background thread.
    """

    def __init__(self, queue_depth: int = 128, pending_limit: int = 64,
                 retries: int = 3, backoff_base: float = 0.01,
                 backoff_cap: float = 0.5, breaker_threshold: int = 3,
                 breaker_reset: float = 1.0,
                 durability_dir: Optional[str] = None,
                 snapshot_every: int = 0, keep_snapshots: int = 3,
                 seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.queue_depth = int(queue_depth)
        self.pending_limit = int(pending_limit)
        self.retries = int(retries)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_reset = float(breaker_reset)
        self.durability_dir = durability_dir
        self.snapshot_every = int(snapshot_every)
        self.keep_snapshots = max(int(keep_snapshots), 1)
        self._rng = random.Random(seed)
        self._clock = clock
        self._sleep = sleep
        self._streams: Dict[str, _Stream] = {}
        self._queue: deque = deque()
        self._admin: deque = deque()   # gc/compact ops, served after queries
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- stream lifecycle ----------------------------------------------------
    def _stream_paths(self, name: str):
        base = os.path.join(self.durability_dir, name)
        return (os.path.join(base, "ckpt"), os.path.join(base, "wal.log"),
                os.path.join(base, "stream.json"))

    def create_stream(self, name: str, spec: MultiSketchSpec,
                      shards: int = 1, **engine_kw) -> SegmentQueryEngine:
        """Register a tenant stream. With a ``durability_dir``, the static
        stream config is persisted (stream.json) so ``EnginePool.open``
        can rebuild the engine even before its first snapshot."""
        if name in self._streams:
            raise ValueError(f"stream {name!r} already exists")
        engine = SegmentQueryEngine(spec, shards=shards, **engine_kw)
        wal = ckpt_dir = None
        if self.durability_dir is not None:
            ckpt_dir, wal_path, cfg_path = self._stream_paths(name)
            os.makedirs(os.path.dirname(cfg_path), exist_ok=True)
            with open(cfg_path, "w") as f:
                json.dump({"multisketch_spec": spec_to_meta(spec),
                           "shards": int(shards),
                           "engine_kw": {k: v for k, v in engine_kw.items()
                                         if k != "use_kernels"}}, f)
                f.flush()
                os.fsync(f.fileno())
            wal = WriteAheadLog(wal_path)
        self._streams[name] = _Stream(
            name, engine,
            CircuitBreaker(self.breaker_threshold, self.breaker_reset,
                           self._clock),
            wal, ckpt_dir)
        return engine

    @classmethod
    def open(cls, durability_dir: str, **kw) -> "EnginePool":
        """Recover a pool from its durability directory: every stream is
        restored from its newest intact checkpoint (falling back across
        corrupt steps), then its WAL tail replayed — bit-identical to the
        uncrashed engines."""
        pool = cls(durability_dir=durability_dir, **kw)
        if os.path.isdir(durability_dir):
            for name in sorted(os.listdir(durability_dir)):
                if os.path.isfile(os.path.join(durability_dir, name,
                                               "stream.json")):
                    pool.restore_stream(name)
        return pool

    def restore_stream(self, name: str) -> SegmentQueryEngine:
        """Restore one stream: checkpoint (if any) -> WAL-tail replay."""
        if self.durability_dir is None:
            raise ValueError("pool has no durability_dir")
        ckpt_dir, wal_path, cfg_path = self._stream_paths(name)
        with open(cfg_path) as f:
            cfg = json.load(f)
        spec = spec_from_meta(cfg["multisketch_spec"])
        applied = 0
        engine = None
        _fault_point("ckpt_restore", name)
        try:
            engine, extra = SegmentQueryEngine.from_checkpoint(
                ckpt_dir, return_meta=True)
            applied = int(extra.get("pool_applied_seq", 0))
        except FileNotFoundError:
            pass                       # pre-first-snapshot: replay-only
        if engine is None:
            engine = SegmentQueryEngine(spec, shards=int(cfg["shards"]),
                                        **cfg.get("engine_kw", {}))
        wal = WriteAheadLog(wal_path)
        st = _Stream(name, engine,
                     CircuitBreaker(self.breaker_threshold,
                                    self.breaker_reset, self._clock),
                     wal, ckpt_dir)
        _fault_point("wal_replay", name)
        seq = applied
        for rec in wal.replay(min_seq_exclusive=applied):
            if rec.shard < 0:
                # GC marker: re-apply the RECORDED victim list, so the
                # restored shard layout matches the uncrashed engine's
                engine.gc_apply([int(x) for x in rec.keys])
            else:
                engine.absorb(rec.keys, rec.weights, rec.active,
                              shard=rec.shard)
            seq = rec.seq
        st.ingest_seq = st.applied_seq = seq
        self._streams[name] = st
        return engine

    def close(self):
        self.stop()
        for st in self._streams.values():
            if st.wal is not None:
                st.wal.close()

    # -- ingest (absorb + quarantine + WAL + retry/breaker) ------------------
    def absorb(self, name: str, keys, weights, shard: int = 0
               ) -> AbsorbReceipt:
        """Ingest one chunk into a tenant stream.

        Order of operations is the durability contract: quarantine ->
        WAL append (fsync) -> device fold with retries. A chunk whose fold
        fails (breaker opens) is still DURABLE and still counted in
        ``ingest_seq`` — queries degrade to ``STALE(epoch_lag)`` until the
        backlog replays. Backlog past ``pending_limit`` sheds load with
        :class:`RejectedError` (bounded memory, never silent loss: the
        rejected chunk was not ack'd)."""
        if shard < 0:
            raise ValueError(
                f"shard must be >= 0, got {shard} (negative values are "
                f"reserved for WAL control records)")
        st = self._stream(name)
        k, w, act, n_bad = quarantine_chunk(keys, weights)
        st.quarantined += n_bad
        accepted = int(np.count_nonzero(act))
        if accepted == 0:
            return AbsorbReceipt(0, n_bad, applied=True,
                                 durable=st.wal is not None,
                                 seq=st.ingest_seq)
        if len(st.pending) >= self.pending_limit:
            raise RejectedError(
                f"stream {name!r} fold backlog full "
                f"({len(st.pending)} chunks)")
        seq = st.ingest_seq + 1
        if st.wal is not None:
            _fault_point("wal_append", name)
            st.wal.append(seq, shard, k, w, act.astype(np.uint8))
        st.ingest_seq = seq
        st.pending.append((seq, int(shard), k, w, act))
        applied = False
        if st.breaker.allow():
            applied = self._drain_pending(st)
            if applied:
                self._maybe_snapshot(st)
        return AbsorbReceipt(accepted, n_bad, applied=applied,
                             durable=st.wal is not None, seq=seq)

    def _drain_pending(self, st: _Stream) -> bool:
        """Fold the backlog in sequence order; True iff fully applied."""
        while st.pending:
            seq, shard, k, w, act = st.pending[0]
            try:
                self._with_retries(
                    lambda: self._fold_one(st, shard, k, w, act), st.name)
            except Exception:
                st.breaker.record_failure()
                return False
            st.breaker.record_success()
            st.pending.popleft()
            st.applied_seq = seq
            st.folds_since_snapshot += 1
        # charge the device work to the ingest path: the folds (and the
        # absorb-time merged-slab maintenance riding them) finish HERE,
        # so the next query never drains this epoch's backlog on its
        # critical path — the zero-merge query contract in wall-clock
        # terms, not just dispatch counts
        st.engine.drain()
        return True

    def _fold_one(self, st: _Stream, shard, k, w, act):
        _fault_point("absorb_fold", st.name)
        st.engine.absorb(k, w, act, shard=shard)

    # -- durability snapshots ------------------------------------------------
    def _maybe_snapshot(self, st: _Stream):
        if (self.snapshot_every and st.ckpt_dir is not None
                and st.folds_since_snapshot >= self.snapshot_every):
            try:
                self.snapshot(st.name)
            except Exception:
                st.snapshot_failures += 1   # WAL still covers everything

    def snapshot(self, name: str):
        """Checkpoint a stream's engine (atomic, crc'd) stamping the
        applied sequence, then prune the WAL to records newer than the
        oldest RETAINED snapshot (recovery from any kept step stays
        possible)."""
        st = self._stream(name)
        if st.ckpt_dir is None:
            raise ValueError(f"stream {name!r} is not durable")
        _fault_point("ckpt_save", name)
        st.engine.save_checkpoint(
            st.ckpt_dir, extra_meta={"pool_applied_seq": st.applied_seq})
        st.folds_since_snapshot = 0
        st.snapshot_seqs.append(st.applied_seq)
        if st.wal is not None and len(st.snapshot_seqs) >= self.keep_snapshots:
            st.wal.prune(st.snapshot_seqs[-self.keep_snapshots])

    # -- admission (submit / pump / query) -----------------------------------
    def submit(self, name: str, fs: Optional[Sequence[StatFn]] = None,
               predicates=EVERYTHING, timeout: Optional[float] = None
               ) -> PoolFuture:
        """Enqueue a segment-query batch; raises :class:`RejectedError`
        when the admission queue is full (load shedding)."""
        st = self._stream(name)
        fs = (tuple(f for f, _ in st.engine.spec.objectives) if fs is None
              else tuple(fs))
        table = np.asarray(encode_predicates(predicates), np.int32)
        fut = PoolFuture()
        deadline = None if timeout is None else self._clock() + timeout
        with self._lock:
            if len(self._queue) >= self.queue_depth:
                raise RejectedError(
                    f"admission queue full ({self.queue_depth})")
            self._queue.append(_Request(name, fs, table, deadline, fut))
        return fut

    def pump(self) -> int:
        """Drain the admission queue once: drop expired requests
        (REJECTED/"deadline"), coalesce the rest by (stream, objectives)
        and serve each group as ONE fused B-bucket launch; then serve at
        most ONE pending admin op (gc/compact) — queries always go first,
        so maintenance never starves reads. Returns the number of
        requests answered (queries + admin)."""
        with self._lock:
            batch = list(self._queue)
            self._queue.clear()
            admin = self._admin.popleft() if self._admin else None
        served = 0
        groups: Dict[Tuple[str, Tuple[StatFn, ...]], list] = {}
        for r in batch:
            if r.deadline is not None and self._clock() > r.deadline:
                r.future._set(Response(REJECTED, error="deadline"))
                continue
            groups.setdefault((r.stream, r.fs), []).append(r)
        served += len(batch)
        for (name, fs), reqs in groups.items():
            table = np.concatenate([r.table for r in reqs])
            resp = self._serve_group(self._stream(name), fs, table)
            col = 0
            for r in reqs:
                b = r.table.shape[0]
                vals = (None if resp.values is None
                        else resp.values[:, col:col + b])
                col += b
                r.future._set(dataclasses.replace(resp, values=vals))
        if admin is not None:
            if (admin.deadline is not None
                    and self._clock() > admin.deadline):
                admin.future._set(Response(REJECTED, error="deadline"))
            else:
                admin.future._set(self._do_gc(self._stream(admin.stream),
                                              admin.max_live,
                                              admin.min_age))
            served += 1
        return served

    def query(self, name: str, fs: Optional[Sequence[StatFn]] = None,
              predicates=EVERYTHING, timeout: Optional[float] = None
              ) -> Response:
        """Synchronous convenience: submit + pump + result. Use
        submit/pump (or ``start()``) for real batched serving."""
        fut = self.submit(name, fs, predicates, timeout)
        self.pump()
        return fut.result(timeout=None if timeout is None else timeout + 1.0)

    # -- admin ops (shard GC / compaction) -----------------------------------
    def request_gc(self, name: str, max_live: Optional[int] = None,
                   min_age: Optional[int] = None,
                   timeout: Optional[float] = None) -> PoolFuture:
        """Enqueue a shard-GC admin op for one stream. Served by ``pump``
        AFTER every pending query (at most one admin op per pump — a
        long compaction can only ever delay other maintenance, never a
        read). Deadline-aware like queries: an op past its deadline is
        answered REJECTED/"deadline". The response's ``gc_victims`` lists
        the shards merged (empty tuple: nothing eligible)."""
        self._stream(name)                 # validate up front
        fut = PoolFuture()
        deadline = None if timeout is None else self._clock() + timeout
        with self._lock:
            self._admin.append(_GcRequest(name, max_live, min_age,
                                          deadline, fut))
        return fut

    def gc(self, name: str, max_live: Optional[int] = None,
           min_age: Optional[int] = None,
           timeout: Optional[float] = None) -> Response:
        """Synchronous shard GC: request + pump + result."""
        fut = self.request_gc(name, max_live, min_age, timeout)
        self.pump()
        return fut.result(timeout=None if timeout is None else timeout + 1.0)

    def compact(self, name: str, timeout: Optional[float] = None
                ) -> Response:
        """Full compaction: merge every live shard into the base slab."""
        return self.gc(name, max_live=1, timeout=timeout)

    def _do_gc(self, st: _Stream, max_live, min_age) -> Response:
        """Apply a shard GC under the durability contract: drain the fold
        backlog first (the plan must see every applied chunk, and the WAL
        marker must sequence AFTER the data it follows), apply the merge,
        THEN append the GC marker. Apply-then-append: a crash between the
        two loses only the GC directive — recovery replays the data into
        the pre-GC layout, whose merged union (hence every answer) is
        identical."""
        if st.pending:
            ok = st.breaker.allow() and self._drain_pending(st)
            if not ok:
                return Response(REJECTED,
                                error="fold backlog not applied (breaker)")
        victims = st.engine.gc_plan(max_live, min_age)
        if not victims:
            return Response(FRESH, gc_victims=())
        try:
            st.engine.gc_apply(victims)
        except Exception as e:
            st.breaker.record_failure()
            return Response(REJECTED, error=f"{type(e).__name__}: {e}")
        err = None
        seq = st.ingest_seq + 1
        if st.wal is not None:
            try:
                _fault_point("wal_append", st.name)
                v = np.asarray(victims, np.int32)
                st.wal.append(seq, GC_SHARD, v,
                              np.zeros(len(victims), np.float32),
                              np.ones(len(victims), np.uint8))
            except Exception as e:
                # GC applied but the marker is lost: recovery replays into
                # the pre-GC layout — same union, so answers are identical
                err = f"gc marker not durable: {type(e).__name__}: {e}"
        st.ingest_seq = seq
        st.applied_seq = seq
        return Response(FRESH, gc_epoch=True, gc_victims=tuple(victims),
                        error=err)

    # -- the degradation ladder ----------------------------------------------
    def _serve_group(self, st: _Stream, fs, table) -> Response:
        err = None
        if st.breaker.allow():
            try:
                vals = self._with_retries(
                    lambda: self._query_engine(st, fs, table), st.name)
                st.breaker.record_success()
                # refresh the degraded-read replica: the handed-out handle
                # stays valid across later donated folds (engine contract)
                st.last_good = (st.applied_seq, st.engine.merged)
                lag = st.ingest_seq - st.applied_seq
                return Response(FRESH if lag == 0 else STALE, vals,
                                epoch_lag=lag,
                                overflow=bool(
                                    st.engine.merge_stats["overflow"]),
                                gc_epoch=(st.engine.last_gc_epoch
                                          == st.engine.epoch))
            except Exception as e:
                st.breaker.record_failure()
                err = f"{type(e).__name__}: {e}"
        # degraded: answer from the last-good merged slab — an older epoch
        # of the SAME unbiased estimator (exact merge contract), labeled
        if st.last_good is not None:
            base_seq, slab = st.last_good
            vals = multisketch_query_many(
                slab, fs, table, b_quantum=st.engine.b_quantum,
                use_kernels=st.engine.use_kernels)
            return Response(STALE, vals,
                            epoch_lag=st.ingest_seq - base_seq,
                            overflow=bool(multisketch_overflow(slab)),
                            error=err)
        return Response(REJECTED, error=err or "breaker open, no last-good")

    def _query_engine(self, st: _Stream, fs, table) -> np.ndarray:
        _fault_point("query_merge", st.name)
        return st.engine.query_many(fs, table)

    def _with_retries(self, fn, stream: str):
        """Exponential backoff + jitter around a failure-prone op."""
        for attempt in range(self.retries + 1):
            try:
                return fn()
            except RejectedError:
                raise
            except Exception:
                if attempt == self.retries:
                    raise
                delay = min(self.backoff_cap,
                            self.backoff_base * (2 ** attempt))
                self._sleep(delay * (0.5 + self._rng.random()))

    # -- background admission loop -------------------------------------------
    def start(self, interval: float = 0.001):
        """Run ``pump`` on a daemon thread until ``stop()``."""
        if self._worker is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() == 0:
                    self._stop.wait(interval)
        self._worker = threading.Thread(target=loop, daemon=True)
        self._worker.start()

    def stop(self):
        if self._worker is not None:
            self._stop.set()
            self._worker.join()
            self._worker = None

    # -- health --------------------------------------------------------------
    def _stream(self, name: str) -> _Stream:
        try:
            return self._streams[name]
        except KeyError:
            raise KeyError(f"unknown stream {name!r}") from None

    @property
    def streams(self):
        return tuple(self._streams)

    def queue_len(self) -> int:
        with self._lock:
            return len(self._queue)

    def stats(self, name: str) -> dict:
        """Health snapshot: staleness lag, quarantine count, breaker
        state, snapshot failures, and the engine's merge/overflow stats."""
        st = self._stream(name)
        return {"ingest_seq": st.ingest_seq, "applied_seq": st.applied_seq,
                "epoch_lag": st.ingest_seq - st.applied_seq,
                "pending": len(st.pending), "quarantined": st.quarantined,
                "breaker_open": st.breaker.is_open,
                "breaker_opens": st.breaker.open_count,
                "snapshot_failures": st.snapshot_failures,
                "gc_epoch": st.engine.last_gc_epoch == st.engine.epoch,
                "merge_stats": dict(st.engine.merge_stats)}
