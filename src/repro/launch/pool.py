"""Fault-tolerant multi-tenant serving tier: the EnginePool.

Many named MultiSketch streams (tenants) behind ONE admission loop, each
stream a resident ``SegmentQueryEngine`` wrapped in the failure machinery
a million-user deployment needs. The design premise is the paper's:
coordinated mergeable sketches make degraded-but-correct answers POSSIBLE
— a stale merged slab is still an unbiased HT estimator with a known
(slightly worse) cv — and the fixed-capacity wire format makes
recovery-by-merge exact. So the pool promises "never wrong, occasionally
stale" instead of "occasionally down":

  * ADMISSION & BACKPRESSURE — a bounded request queue; ``submit`` raises
    :class:`RejectedError` when it is full (load shedding, never unbounded
    memory). ``pump`` drains the queue and COALESCES same-(stream,
    objectives) requests into one fused B-bucket launch (the
    ``multisketch_query_many`` quantum machinery), so burst traffic pays
    one kernel launch per bucket, not one per request. Per-request
    deadlines: a request already past its deadline at service time is
    answered ``REJECTED`` (error "deadline"), never silently late.
  * RETRY / TIMEOUT / BACKOFF — transient absorb/query failures (injected
    device errors, donation races) are retried with exponential backoff +
    jitter; persistent failure trips a per-stream circuit breaker.
  * GRACEFUL DEGRADATION LADDER — ``FRESH`` -> ``STALE(epoch_lag)`` ->
    ``REJECTED``. A stream whose breaker is open (or whose fresh query
    path fails after retries) serves from its LAST-GOOD merged slab; a
    failed delta fold leaves data durable in the WAL and downgrades
    responses to ``STALE`` with the exact chunk lag. Every response
    carries its staleness level and the ``multisketch_overflow`` flag —
    degraded answers are still unbiased estimates, and they are LABELED.
  * INPUT QUARANTINE — NaN/inf/negative rows are rejected PER ROW at
    absorb (``core.multi_sketch.quarantine_chunk``) with a per-stream
    counter: one bad producer cannot poison a tenant's slab.
  * DURABILITY — per-stream WAL of absorbed chunks (``launch.wal``,
    fsync'd write-ahead of the fold) + periodic ``CheckpointManager``
    snapshots. Crash recovery = restore newest intact snapshot -> replay
    the WAL tail -> lazy merge, BIT-IDENTICAL to the uncrashed engine
    (asserted in tests/test_serving_faults.py).
  * ADMIN OPS — ``request_gc``/``gc``/``compact`` ride a separate admin
    queue on the same admission loop: each ``pump`` serves EVERY pending
    query first, then at most ONE admin op (GC never starves reads), with
    the same deadline semantics. A GC drains the stream's fold backlog,
    applies the engine's shard GC (``gc_plan``/``gc_apply``), then
    appends a WAL GC marker (``wal.GC_SHARD``) carrying the victim list —
    apply-then-append, so recovery replays the recorded decision and
    lands in the identical post-GC shard layout. Responses served while
    the engine's newest epoch is a GC epoch are labeled ``gc_epoch``.

Fault-injection hooks: every failure-prone operation funnels through a
named fault point (``_fault_point``); the chaos harness (tests/faults.py)
installs deterministic failure schedules there without monkeypatching
library internals. Production runs have zero hooks installed and pay one
dict lookup per operation.

SCALE-OUT (``ShardedEnginePool``): the multi-HOST tier over the same
machinery. Each named stream's shards are partitioned across a host group
by rendezvous (consistent-hash) placement over the existing shard
indices; absorbs fan out to the owner host's resident engine, and queries
merge the per-host merged slabs through ONE stacked re-selection
(``launch.summary.merge_host_slabs`` — the step-3 path, exact by
threshold closure and bit-identical to a single-host union engine). Each
stream's last-good merged slab is replicated to a primary + one FOLLOWER
host on every successful read, so queries survive a host loss at STALE
status (coordinated replicas serve bit-compatible answers — the shared
hash seeds, arXiv 0906.4560). Membership change is driven entirely by WAL
replay: a ``REBALANCE`` marker (``wal.REBALANCE_SHARD``) logs the full
shard->host re-partition under the same apply-then-append discipline as
GC markers, so recovery replays data + GC + rebalance markers in seq
order into the identical post-move layout — and a marker lost to a crash
merely recovers the PRE-move placement, whose merged union (hence every
answer) is bit-identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import struct
import threading
import time
import zlib
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.funcs import StatFn
from repro.core.multi_sketch import (MultiSketch, MultiSketchSpec,
                                     multisketch_overflow,
                                     multisketch_query_many,
                                     quarantine_chunk, spec_from_meta,
                                     spec_to_meta)
from repro.core.predicates import EVERYTHING, encode_predicates
from repro.launch.query import SegmentQueryEngine
from repro.launch.summary import merge_host_slabs
from repro.launch.wal import GC_SHARD, REBALANCE_SHARD, WriteAheadLog

# degradation-ladder response statuses (the serving contract, core.merge)
FRESH = "FRESH"
STALE = "STALE"
REJECTED = "REJECTED"


class RejectedError(RuntimeError):
    """Load shed: admission queue full / absorb backlog over its bound."""


class TransientFault(RuntimeError):
    """A retryable failure (injected device error, donation race)."""


class HostDownError(RuntimeError):
    """A scale-out operation targeted a dead host. NOT retryable: the
    host stays dead until a rebalance moves its shards — callers degrade
    immediately (replica read / pending backlog) instead of burning the
    retry budget."""


# -- fault-injection points (chaos harness contract) ------------------------
# name -> hook(stream_name); an installed hook RAISES to inject a fault.
# ``host_op`` fires once per per-host engine operation of the scale-out
# pool, with the label "<stream>@h<host_id>" — host-kill schedules hook it
# to drop a host at a deterministic operation index (tests/faults.py).
_FAULT_HOOKS: Dict[str, Callable[[str], None]] = {}

FAULT_POINTS = ("absorb_fold", "query_merge", "wal_append", "wal_replay",
                "ckpt_save", "ckpt_restore", "host_op")


def install_fault_hook(point: str, fn: Callable[[str], None]):
    if point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}")
    _FAULT_HOOKS[point] = fn


def clear_fault_hooks():
    _FAULT_HOOKS.clear()


def _fault_point(point: str, stream: str):
    fn = _FAULT_HOOKS.get(point)
    if fn is not None:
        fn(stream)


def _retry_loop(fn, *, retries: int, backoff_base: float, backoff_cap: float,
                rng: random.Random, sleep: Callable[[float], None]):
    """Exponential backoff + jitter around a failure-prone op (shared by
    the single-host and scale-out pools). ``RejectedError`` (load shed)
    and ``HostDownError`` (dead until rebalanced) are not transient and
    propagate immediately."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except (RejectedError, HostDownError):
            raise
        except Exception:
            if attempt == retries:
                raise
            delay = min(backoff_cap, backoff_base * (2 ** attempt))
            sleep(delay * (0.5 + rng.random()))


# -- responses ---------------------------------------------------------------

@dataclasses.dataclass
class Response:
    """One answered query. ``values`` is float [|F|, B] (None iff
    REJECTED); ``epoch_lag`` counts accepted-but-unreflected absorb chunks
    (0 iff the answer covers every ack'd chunk); ``overflow`` mirrors
    ``multisketch_overflow`` of the slab that produced the answer."""

    status: str
    values: Optional[np.ndarray] = None
    epoch_lag: int = 0
    overflow: bool = False
    error: Optional[str] = None
    # the served slab's newest epoch was produced by a shard-GC merge
    # (same union, compacted layout) — labeled, like staleness
    gc_epoch: bool = False
    # admin-op (gc/compact) responses only: victim shards merged
    gc_victims: Optional[Tuple[int, ...]] = None

    @property
    def ok(self) -> bool:
        return self.status != REJECTED


@dataclasses.dataclass
class AbsorbReceipt:
    """Ack for one absorb: rows accepted (durable once ``durable``),
    rows quarantined, and whether the device fold already applied."""

    accepted: int
    quarantined: int
    applied: bool
    durable: bool
    seq: int = 0


class PoolFuture:
    """Completion handle for a submitted query."""

    def __init__(self):
        self._event = threading.Event()
        self._response: Optional[Response] = None

    def _set(self, response: Response):
        self._response = response
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Response:
        if not self._event.wait(timeout):
            raise TimeoutError("query not served within timeout")
        return self._response


@dataclasses.dataclass
class _Request:
    stream: str
    fs: Tuple[StatFn, ...]
    table: np.ndarray           # encoded predicate rows [b, PRED_COLS]
    deadline: Optional[float]
    future: PoolFuture


@dataclasses.dataclass
class _GcRequest:
    stream: str
    max_live: Optional[int]
    min_age: Optional[int]
    deadline: Optional[float]
    future: PoolFuture


class CircuitBreaker:
    """Consecutive-failure breaker: closed -> open after ``threshold``
    failures; open admits one half-open probe after ``reset_after``
    seconds; a probe success closes it, a probe failure re-opens."""

    def __init__(self, threshold: int = 3, reset_after: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = int(threshold)
        self.reset_after = float(reset_after)
        self._clock = clock
        self._failures = 0
        self._opened_at: Optional[float] = None
        self.open_count = 0     # times the breaker tripped (health metric)

    @property
    def is_open(self) -> bool:
        return self._opened_at is not None

    def allow(self) -> bool:
        """May the protected operation be ATTEMPTED now? True when closed,
        or when open long enough for a half-open probe."""
        if self._opened_at is None:
            return True
        return self._clock() - self._opened_at >= self.reset_after

    def record_success(self):
        self._failures = 0
        self._opened_at = None

    def record_failure(self):
        self._failures += 1
        if self._failures >= self.threshold:
            if self._opened_at is None:
                self.open_count += 1
            self._opened_at = self._clock()


class _Stream:
    """One tenant: engine + breaker + WAL + staleness bookkeeping."""

    def __init__(self, name: str, engine: SegmentQueryEngine,
                 breaker: CircuitBreaker, wal: Optional[WriteAheadLog],
                 ckpt_dir: Optional[str]):
        self.name = name
        self.engine = engine
        self.breaker = breaker
        self.wal = wal
        self.ckpt_dir = ckpt_dir
        self.ingest_seq = 0       # chunks accepted (and WAL'd, if durable)
        self.applied_seq = 0      # chunks folded into the engine
        self.quarantined = 0      # malformed rows rejected per-row
        self.snapshot_failures = 0
        self.folds_since_snapshot = 0
        self.snapshot_seqs: list = []      # applied_seq at each snapshot
        # (applied_seq_at_capture, merged slab) — the degraded-read replica
        self.last_good = None
        # fold backlog: chunks ack'd (durable) but not yet applied —
        # bounded; the WAL holds them too, this just avoids re-reading it
        self.pending = deque()


class EnginePool:
    """Multi-tenant serving pool. See module docstring for the contract.

    ``pump`` is the admission loop body: call it from your serving loop
    (deterministic — what the tests and the chaos bench do) or let
    ``start()`` run it on a background thread.
    """

    def __init__(self, queue_depth: int = 128, pending_limit: int = 64,
                 retries: int = 3, backoff_base: float = 0.01,
                 backoff_cap: float = 0.5, breaker_threshold: int = 3,
                 breaker_reset: float = 1.0,
                 durability_dir: Optional[str] = None,
                 snapshot_every: int = 0, keep_snapshots: int = 3,
                 seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.queue_depth = int(queue_depth)
        self.pending_limit = int(pending_limit)
        self.retries = int(retries)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_reset = float(breaker_reset)
        self.durability_dir = durability_dir
        self.snapshot_every = int(snapshot_every)
        self.keep_snapshots = max(int(keep_snapshots), 1)
        self._rng = random.Random(seed)
        self._clock = clock
        self._sleep = sleep
        self._streams: Dict[str, _Stream] = {}
        self._queue: deque = deque()
        self._admin: deque = deque()   # gc/compact ops, served after queries
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- stream lifecycle ----------------------------------------------------
    def _stream_paths(self, name: str):
        base = os.path.join(self.durability_dir, name)
        return (os.path.join(base, "ckpt"), os.path.join(base, "wal.log"),
                os.path.join(base, "stream.json"))

    def create_stream(self, name: str, spec: MultiSketchSpec,
                      shards: int = 1, **engine_kw) -> SegmentQueryEngine:
        """Register a tenant stream. With a ``durability_dir``, the static
        stream config is persisted (stream.json) so ``EnginePool.open``
        can rebuild the engine even before its first snapshot."""
        if name in self._streams:
            raise ValueError(f"stream {name!r} already exists")
        engine = SegmentQueryEngine(spec, shards=shards, **engine_kw)
        wal = ckpt_dir = None
        if self.durability_dir is not None:
            ckpt_dir, wal_path, cfg_path = self._stream_paths(name)
            os.makedirs(os.path.dirname(cfg_path), exist_ok=True)
            with open(cfg_path, "w") as f:
                json.dump({"multisketch_spec": spec_to_meta(spec),
                           "shards": int(shards),
                           "engine_kw": {k: v for k, v in engine_kw.items()
                                         if k != "use_kernels"}}, f)
                f.flush()
                os.fsync(f.fileno())
            wal = WriteAheadLog(wal_path)
        self._streams[name] = _Stream(
            name, engine,
            CircuitBreaker(self.breaker_threshold, self.breaker_reset,
                           self._clock),
            wal, ckpt_dir)
        return engine

    @classmethod
    def open(cls, durability_dir: str, **kw) -> "EnginePool":
        """Recover a pool from its durability directory: every stream is
        restored from its newest intact checkpoint (falling back across
        corrupt steps), then its WAL tail replayed — bit-identical to the
        uncrashed engines."""
        pool = cls(durability_dir=durability_dir, **kw)
        if os.path.isdir(durability_dir):
            for name in sorted(os.listdir(durability_dir)):
                if os.path.isfile(os.path.join(durability_dir, name,
                                               "stream.json")):
                    pool.restore_stream(name)
        return pool

    def restore_stream(self, name: str) -> SegmentQueryEngine:
        """Restore one stream: checkpoint (if any) -> WAL-tail replay."""
        if self.durability_dir is None:
            raise ValueError("pool has no durability_dir")
        ckpt_dir, wal_path, cfg_path = self._stream_paths(name)
        with open(cfg_path) as f:
            cfg = json.load(f)
        spec = spec_from_meta(cfg["multisketch_spec"])
        applied = 0
        engine = None
        _fault_point("ckpt_restore", name)
        try:
            engine, extra = SegmentQueryEngine.from_checkpoint(
                ckpt_dir, return_meta=True)
            applied = int(extra.get("pool_applied_seq", 0))
        except FileNotFoundError:
            pass                       # pre-first-snapshot: replay-only
        if engine is None:
            engine = SegmentQueryEngine(spec, shards=int(cfg["shards"]),
                                        **cfg.get("engine_kw", {}))
        wal = WriteAheadLog(wal_path)
        st = _Stream(name, engine,
                     CircuitBreaker(self.breaker_threshold,
                                    self.breaker_reset, self._clock),
                     wal, ckpt_dir)
        _fault_point("wal_replay", name)
        seq = applied
        for rec in wal.replay(min_seq_exclusive=applied):
            if rec.shard < 0:
                # GC marker: re-apply the RECORDED victim list, so the
                # restored shard layout matches the uncrashed engine's
                engine.gc_apply([int(x) for x in rec.keys])
            else:
                engine.absorb(rec.keys, rec.weights, rec.active,
                              shard=rec.shard)
            seq = rec.seq
        st.ingest_seq = st.applied_seq = seq
        self._streams[name] = st
        return engine

    def close(self):
        self.stop()
        for st in self._streams.values():
            if st.wal is not None:
                st.wal.close()

    # -- ingest (absorb + quarantine + WAL + retry/breaker) ------------------
    def absorb(self, name: str, keys, weights, shard: int = 0
               ) -> AbsorbReceipt:
        """Ingest one chunk into a tenant stream.

        Order of operations is the durability contract: quarantine ->
        WAL append (fsync) -> device fold with retries. A chunk whose fold
        fails (breaker opens) is still DURABLE and still counted in
        ``ingest_seq`` — queries degrade to ``STALE(epoch_lag)`` until the
        backlog replays. Backlog past ``pending_limit`` sheds load with
        :class:`RejectedError` (bounded memory, never silent loss: the
        rejected chunk was not ack'd)."""
        if shard < 0:
            raise ValueError(
                f"shard must be >= 0, got {shard} (negative values are "
                f"reserved for WAL control records)")
        st = self._stream(name)
        k, w, act, n_bad = quarantine_chunk(keys, weights)
        st.quarantined += n_bad
        accepted = int(np.count_nonzero(act))
        if accepted == 0:
            return AbsorbReceipt(0, n_bad, applied=True,
                                 durable=st.wal is not None,
                                 seq=st.ingest_seq)
        if len(st.pending) >= self.pending_limit:
            raise RejectedError(
                f"stream {name!r} fold backlog full "
                f"({len(st.pending)} chunks)")
        seq = st.ingest_seq + 1
        if st.wal is not None:
            _fault_point("wal_append", name)
            st.wal.append(seq, shard, k, w, act.astype(np.uint8))
        st.ingest_seq = seq
        st.pending.append((seq, int(shard), k, w, act))
        applied = False
        if st.breaker.allow():
            applied = self._drain_pending(st)
            if applied:
                self._maybe_snapshot(st)
        return AbsorbReceipt(accepted, n_bad, applied=applied,
                             durable=st.wal is not None, seq=seq)

    def _drain_pending(self, st: _Stream) -> bool:
        """Fold the backlog in sequence order; True iff fully applied."""
        while st.pending:
            seq, shard, k, w, act = st.pending[0]
            try:
                self._with_retries(
                    lambda: self._fold_one(st, shard, k, w, act), st.name)
            except Exception:
                st.breaker.record_failure()
                return False
            st.breaker.record_success()
            st.pending.popleft()
            st.applied_seq = seq
            st.folds_since_snapshot += 1
        # charge the device work to the ingest path: the folds (and the
        # absorb-time merged-slab maintenance riding them) finish HERE,
        # so the next query never drains this epoch's backlog on its
        # critical path — the zero-merge query contract in wall-clock
        # terms, not just dispatch counts
        st.engine.drain()
        return True

    def _fold_one(self, st: _Stream, shard, k, w, act):
        _fault_point("absorb_fold", st.name)
        st.engine.absorb(k, w, act, shard=shard)

    # -- durability snapshots ------------------------------------------------
    def _maybe_snapshot(self, st: _Stream):
        if (self.snapshot_every and st.ckpt_dir is not None
                and st.folds_since_snapshot >= self.snapshot_every):
            try:
                self.snapshot(st.name)
            except Exception:
                st.snapshot_failures += 1   # WAL still covers everything

    def snapshot(self, name: str):
        """Checkpoint a stream's engine (atomic, crc'd) stamping the
        applied sequence, then prune the WAL to records newer than the
        oldest RETAINED snapshot (recovery from any kept step stays
        possible)."""
        st = self._stream(name)
        if st.ckpt_dir is None:
            raise ValueError(f"stream {name!r} is not durable")
        _fault_point("ckpt_save", name)
        st.engine.save_checkpoint(
            st.ckpt_dir, extra_meta={"pool_applied_seq": st.applied_seq})
        st.folds_since_snapshot = 0
        st.snapshot_seqs.append(st.applied_seq)
        if st.wal is not None and len(st.snapshot_seqs) >= self.keep_snapshots:
            st.wal.prune(st.snapshot_seqs[-self.keep_snapshots])

    # -- admission (submit / pump / query) -----------------------------------
    def submit(self, name: str, fs: Optional[Sequence[StatFn]] = None,
               predicates=EVERYTHING, timeout: Optional[float] = None
               ) -> PoolFuture:
        """Enqueue a segment-query batch; raises :class:`RejectedError`
        when the admission queue is full (load shedding)."""
        st = self._stream(name)
        fs = (tuple(f for f, _ in st.engine.spec.objectives) if fs is None
              else tuple(fs))
        table = np.asarray(encode_predicates(predicates), np.int32)
        fut = PoolFuture()
        deadline = None if timeout is None else self._clock() + timeout
        with self._lock:
            if len(self._queue) >= self.queue_depth:
                raise RejectedError(
                    f"admission queue full ({self.queue_depth})")
            self._queue.append(_Request(name, fs, table, deadline, fut))
        return fut

    def pump(self) -> int:
        """Drain the admission queue once: drop expired requests
        (REJECTED/"deadline"), coalesce the rest by (stream, objectives)
        and serve each group as ONE fused B-bucket launch; then serve at
        most ONE pending admin op (gc/compact) — queries always go first,
        so maintenance never starves reads. Returns the number of
        requests answered (queries + admin)."""
        with self._lock:
            batch = list(self._queue)
            self._queue.clear()
            admin = self._admin.popleft() if self._admin else None
        served = 0
        groups: Dict[Tuple[str, Tuple[StatFn, ...]], list] = {}
        for r in batch:
            # >= : a deadline EQUAL to now is already expired — timeout=0
            # must shed, not serve (a zero budget can never be met)
            if r.deadline is not None and self._clock() >= r.deadline:
                r.future._set(Response(REJECTED, error="deadline"))
                continue
            groups.setdefault((r.stream, r.fs), []).append(r)
        served += len(batch)
        for (name, fs), reqs in groups.items():
            table = np.concatenate([r.table for r in reqs])
            resp = self._serve_group(self._stream(name), fs, table)
            col = 0
            for r in reqs:
                b = r.table.shape[0]
                vals = (None if resp.values is None
                        else resp.values[:, col:col + b])
                col += b
                r.future._set(dataclasses.replace(resp, values=vals))
        if admin is not None:
            if (admin.deadline is not None
                    and self._clock() >= admin.deadline):
                admin.future._set(Response(REJECTED, error="deadline"))
            else:
                admin.future._set(self._do_gc(self._stream(admin.stream),
                                              admin.max_live,
                                              admin.min_age))
            served += 1
        return served

    def query(self, name: str, fs: Optional[Sequence[StatFn]] = None,
              predicates=EVERYTHING, timeout: Optional[float] = None
              ) -> Response:
        """Synchronous convenience: submit + pump + result. Use
        submit/pump (or ``start()``) for real batched serving."""
        fut = self.submit(name, fs, predicates, timeout)
        self.pump()
        return fut.result(timeout=None if timeout is None else timeout + 1.0)

    # -- admin ops (shard GC / compaction) -----------------------------------
    def request_gc(self, name: str, max_live: Optional[int] = None,
                   min_age: Optional[int] = None,
                   timeout: Optional[float] = None) -> PoolFuture:
        """Enqueue a shard-GC admin op for one stream. Served by ``pump``
        AFTER every pending query (at most one admin op per pump — a
        long compaction can only ever delay other maintenance, never a
        read). Deadline-aware like queries: an op past its deadline is
        answered REJECTED/"deadline". The response's ``gc_victims`` lists
        the shards merged (empty tuple: nothing eligible)."""
        self._stream(name)                 # validate up front
        fut = PoolFuture()
        deadline = None if timeout is None else self._clock() + timeout
        with self._lock:
            self._admin.append(_GcRequest(name, max_live, min_age,
                                          deadline, fut))
        return fut

    def gc(self, name: str, max_live: Optional[int] = None,
           min_age: Optional[int] = None,
           timeout: Optional[float] = None) -> Response:
        """Synchronous shard GC: request + pump + result."""
        fut = self.request_gc(name, max_live, min_age, timeout)
        self.pump()
        return fut.result(timeout=None if timeout is None else timeout + 1.0)

    def compact(self, name: str, timeout: Optional[float] = None
                ) -> Response:
        """Full compaction: merge every live shard into the base slab."""
        return self.gc(name, max_live=1, timeout=timeout)

    def _do_gc(self, st: _Stream, max_live, min_age) -> Response:
        """Apply a shard GC under the durability contract: drain the fold
        backlog first (the plan must see every applied chunk, and the WAL
        marker must sequence AFTER the data it follows), apply the merge,
        THEN append the GC marker. Apply-then-append: a crash between the
        two loses only the GC directive — recovery replays the data into
        the pre-GC layout, whose merged union (hence every answer) is
        identical."""
        if st.pending:
            ok = st.breaker.allow() and self._drain_pending(st)
            if not ok:
                return Response(REJECTED,
                                error="fold backlog not applied (breaker)")
        victims = st.engine.gc_plan(max_live, min_age)
        if not victims:
            return Response(FRESH, gc_victims=())
        try:
            st.engine.gc_apply(victims)
        except Exception as e:
            st.breaker.record_failure()
            return Response(REJECTED, error=f"{type(e).__name__}: {e}")
        err = None
        seq = st.ingest_seq + 1
        if st.wal is not None:
            try:
                _fault_point("wal_append", st.name)
                v = np.asarray(victims, np.int32)
                st.wal.append(seq, GC_SHARD, v,
                              np.zeros(len(victims), np.float32),
                              np.ones(len(victims), np.uint8))
            except Exception as e:
                # GC applied but the marker is lost: recovery replays into
                # the pre-GC layout — same union, so answers are identical
                err = f"gc marker not durable: {type(e).__name__}: {e}"
        st.ingest_seq = seq
        st.applied_seq = seq
        return Response(FRESH, gc_epoch=True, gc_victims=tuple(victims),
                        error=err)

    # -- the degradation ladder ----------------------------------------------
    def _serve_group(self, st: _Stream, fs, table) -> Response:
        err = None
        if st.breaker.allow():
            try:
                vals = self._with_retries(
                    lambda: self._query_engine(st, fs, table), st.name)
                st.breaker.record_success()
                # refresh the degraded-read replica: the handed-out handle
                # stays valid across later donated folds (engine contract)
                st.last_good = (st.applied_seq, st.engine.merged)
                lag = st.ingest_seq - st.applied_seq
                return Response(FRESH if lag == 0 else STALE, vals,
                                epoch_lag=lag,
                                overflow=bool(
                                    st.engine.merge_stats["overflow"]),
                                gc_epoch=(st.engine.last_gc_epoch
                                          == st.engine.epoch))
            except Exception as e:
                st.breaker.record_failure()
                err = f"{type(e).__name__}: {e}"
        # degraded: answer from the last-good merged slab — an older epoch
        # of the SAME unbiased estimator (exact merge contract), labeled
        if st.last_good is not None:
            base_seq, slab = st.last_good
            vals = multisketch_query_many(
                slab, fs, table, b_quantum=st.engine.b_quantum,
                use_kernels=st.engine.use_kernels)
            return Response(STALE, vals,
                            epoch_lag=st.ingest_seq - base_seq,
                            overflow=bool(multisketch_overflow(slab)),
                            error=err)
        return Response(REJECTED, error=err or "breaker open, no last-good")

    def _query_engine(self, st: _Stream, fs, table) -> np.ndarray:
        _fault_point("query_merge", st.name)
        return st.engine.query_many(fs, table)

    def _with_retries(self, fn, stream: str):
        """Exponential backoff + jitter around a failure-prone op."""
        return _retry_loop(fn, retries=self.retries,
                           backoff_base=self.backoff_base,
                           backoff_cap=self.backoff_cap,
                           rng=self._rng, sleep=self._sleep)

    # -- background admission loop -------------------------------------------
    def start(self, interval: float = 0.001):
        """Run ``pump`` on a daemon thread until ``stop()``."""
        if self._worker is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() == 0:
                    self._stop.wait(interval)
        self._worker = threading.Thread(target=loop, daemon=True)
        self._worker.start()

    def stop(self):
        if self._worker is not None:
            self._stop.set()
            self._worker.join()
            self._worker = None

    # -- health --------------------------------------------------------------
    def _stream(self, name: str) -> _Stream:
        try:
            return self._streams[name]
        except KeyError:
            raise KeyError(f"unknown stream {name!r}") from None

    @property
    def streams(self):
        return tuple(self._streams)

    def queue_len(self) -> int:
        with self._lock:
            return len(self._queue)

    def stats(self, name: str) -> dict:
        """Health snapshot: staleness lag, quarantine count, breaker
        state, snapshot failures, and the engine's merge/overflow stats."""
        st = self._stream(name)
        return {"ingest_seq": st.ingest_seq, "applied_seq": st.applied_seq,
                "epoch_lag": st.ingest_seq - st.applied_seq,
                "pending": len(st.pending), "quarantined": st.quarantined,
                "breaker_open": st.breaker.is_open,
                "breaker_opens": st.breaker.open_count,
                "snapshot_failures": st.snapshot_failures,
                "gc_epoch": st.engine.last_gc_epoch == st.engine.epoch,
                "merge_stats": dict(st.engine.merge_stats)}


# ===========================================================================
# Scale-out: the multi-host pool
# ===========================================================================

def rendezvous_owner(shard: int, hosts: Sequence[int]) -> int:
    """Consistent-hash owner of one shard over a host set: highest-random-
    weight (rendezvous) hashing on ``crc32(shard, host)``. Deterministic
    across processes (crc32, not the salted builtin ``hash``), and MINIMAL
    under membership change: removing a host moves only ITS shards,
    adding one steals only the shards it now wins — every other shard
    keeps its owner, so a rebalance hand-off is O(moved), not O(shards)."""
    best = -1
    best_score = -1
    for h in sorted(int(x) for x in hosts):
        score = zlib.crc32(struct.pack("<qq", int(shard), h))
        if score > best_score:
            best, best_score = h, score
    if best < 0:
        raise ValueError("rendezvous over an empty host set")
    return best


def compute_placement(shards: int, hosts: Sequence[int]) -> List[int]:
    """shard index -> owner host id, for every global shard."""
    return [rendezvous_owner(s, hosts) for s in range(int(shards))]


@dataclasses.dataclass
class _Host:
    """One simulated host of the group: per-stream resident engines plus
    the replicated last-good slabs it holds for degraded reads. A kill
    drops everything in-memory — only the WAL/checkpoints survive."""

    hid: int
    alive: bool = True
    engines: Dict[str, SegmentQueryEngine] = dataclasses.field(
        default_factory=dict)
    # stream -> (applied_seq_at_capture, merged slab): the follower copy
    replicas: Dict[str, Tuple[int, MultiSketch]] = dataclasses.field(
        default_factory=dict)


class _ShardedStream:
    """One scale-out tenant: placement + WAL + staleness bookkeeping.

    The per-host data lives in the hosts' engines; this object owns only
    what must survive host churn — the shard->host placement, the ingest/
    applied sequence frontier, and the durable handles."""

    def __init__(self, name: str, spec: MultiSketchSpec, shards: int,
                 engine_kw: dict, wal: Optional[WriteAheadLog],
                 ckpt_dir: Optional[str], initial_hosts: Sequence[int]):
        self.name = name
        self.spec = spec
        self.shards = int(shards)
        self.engine_kw = dict(engine_kw)
        self.b_quantum = int(self.engine_kw.get("b_quantum", 16))
        self.use_kernels = self.engine_kw.get("use_kernels")
        self.wal = wal
        self.ckpt_dir = ckpt_dir
        # creation-time host set: the replay BASE — recovery recomputes
        # this placement first, then folds REBALANCE markers over it, so
        # the placement chain is reproducible from stream.json alone
        self.initial_hosts = tuple(int(h) for h in initial_hosts)
        self.placement: List[int] = compute_placement(shards,
                                                      self.initial_hosts)
        self.placement_version = 0
        self.ingest_seq = 0       # chunks accepted (and WAL'd, if durable)
        self.applied_seq = 0      # prefix folded into owner engines
        self.quarantined = 0
        self.folds_since_snapshot = 0
        self.snapshot_seqs: list = []
        # fold backlog: ack'd (durable) but not yet applied — chunks whose
        # owner host is dead (or whose fold faulted) wait here; the WAL
        # holds them too, so a rebalance can rebuild them bit-exactly
        self.pending = deque()
        # cross-host merged slab, memoized on (placement_version, per-owner
        # engine epochs): steady-state reads pay ZERO merge work
        self.cross_cache: Optional[tuple] = None
        self.cross_merges = 0     # stacked re-selections actually run


class ShardedEnginePool:
    """Multi-host serving pool: shards partitioned across a host group.

    The single-host ``EnginePool`` contract ("never wrong, occasionally
    stale"), horizontally scaled — see the module docstring's SCALE-OUT
    section for the placement / replication / rebalance design. In-process
    hosts model the failure domains: ``kill_host`` drops one host's
    resident engines and replicas exactly as a machine loss would, and the
    durability story (WAL + snapshots + markers) is what brings its shards
    back, bit-identically, on another host.

    Write path: quarantine -> WAL append -> fold on the owner host (with
    retries; a dead owner leaves the chunk pending and queries STALE).
    Read path: one stacked re-selection over the live owners' merged
    slabs, memoized per (placement, engine epochs); on failure the newest
    surviving replica serves at STALE; only a total wipe answers REJECTED.
    """

    def __init__(self, hosts: Sequence[int] = (0, 1, 2, 3),
                 pending_limit: int = 64,
                 retries: int = 3, backoff_base: float = 0.01,
                 backoff_cap: float = 0.5,
                 durability_dir: Optional[str] = None,
                 snapshot_every: int = 0, keep_snapshots: int = 3,
                 seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        ids = sorted({int(h) for h in hosts})
        if not ids:
            raise ValueError("need >= 1 host")
        self._hosts: Dict[int, _Host] = {h: _Host(h) for h in ids}
        self.pending_limit = int(pending_limit)
        self.retries = int(retries)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.durability_dir = durability_dir
        self.snapshot_every = int(snapshot_every)
        self.keep_snapshots = max(int(keep_snapshots), 1)
        self._rng = random.Random(seed)
        self._clock = clock
        self._sleep = sleep
        self._streams: Dict[str, _ShardedStream] = {}
        if durability_dir is not None:
            os.makedirs(durability_dir, exist_ok=True)
            self._save_hosts()

    # -- host membership -----------------------------------------------------
    @property
    def hosts(self) -> Tuple[int, ...]:
        return tuple(sorted(self._hosts))

    @property
    def live_hosts(self) -> Tuple[int, ...]:
        return tuple(h for h in sorted(self._hosts)
                     if self._hosts[h].alive)

    def _hosts_path(self) -> str:
        return os.path.join(self.durability_dir, "hosts.json")

    def _save_hosts(self):
        with open(self._hosts_path(), "w") as f:
            json.dump({"hosts": list(self.hosts)}, f)
            f.flush()
            os.fsync(f.fileno())

    def kill_host(self, hid: int):
        """Simulate losing one host: its resident engines AND replicas
        vanish (in-memory state only — the WAL and checkpoints are the
        surviving copy). Queries over streams whose shards it owned
        degrade to the newest surviving replica (STALE) until
        ``rebalance`` re-partitions; absorbs destined to it stay pending
        (durable, ack'd). Membership (hosts.json) is NOT rewritten: a
        full-pool restart may bring the machine back, and WAL-replayed
        placement decides what it serves again."""
        h = self._host(hid)
        h.alive = False
        h.engines = {}
        h.replicas = {}
        for st in self._streams.values():
            st.cross_cache = None

    def host_join(self, hid: int):
        """Add a new (empty) host to the group. Placement is unchanged
        until the caller runs ``rebalance`` — joining is cheap, moving
        data is the explicit, WAL-marked step."""
        hid = int(hid)
        if hid in self._hosts:
            raise ValueError(f"host {hid} already in the group")
        self._hosts[hid] = _Host(hid)
        if self.durability_dir is not None:
            self._save_hosts()

    def host_leave(self, hid: int):
        """Graceful decommission: rebalance every stream's shards OFF the
        host (live hand-offs, REBALANCE markers) while it is still alive,
        then drop it from the group."""
        h = self._host(hid)
        if h.alive and len(self.live_hosts) <= 1:
            raise RuntimeError("cannot decommission the last live host")
        if h.alive:
            self.rebalance(exclude=(hid,))
        del self._hosts[hid]
        if self.durability_dir is not None:
            self._save_hosts()

    def _host(self, hid: int) -> _Host:
        try:
            return self._hosts[int(hid)]
        except KeyError:
            raise KeyError(f"unknown host {hid!r}") from None

    def _host_alive(self, hid: int) -> bool:
        h = self._hosts.get(int(hid))
        return h is not None and h.alive

    def _host_engine(self, st: _ShardedStream, host: _Host
                     ) -> SegmentQueryEngine:
        """The host's resident engine for one stream, created on first
        touch. Engines are FULL-WIDTH (every global shard): un-owned
        shards stay parked on the shared inert slab, so residency is
        O(owned live shards) while global shard indices address any host
        uniformly (placement can move shards without renumbering)."""
        eng = host.engines.get(st.name)
        if eng is None:
            eng = SegmentQueryEngine(st.spec, shards=st.shards,
                                     **st.engine_kw)
            host.engines[st.name] = eng
        return eng

    # -- stream lifecycle ----------------------------------------------------
    def _stream_paths(self, name: str):
        base = os.path.join(self.durability_dir, name)
        return (os.path.join(base, "ckpt"), os.path.join(base, "wal.log"),
                os.path.join(base, "stream.json"))

    def create_stream(self, name: str, spec: MultiSketchSpec,
                      shards: int = 4, **engine_kw) -> Tuple[int, ...]:
        """Register a tenant stream, partitioned over the CURRENT live
        hosts; returns the shard->host placement. With a
        ``durability_dir`` the static config (spec, shard count, the
        creation-time host set that seeds placement replay) is persisted
        so ``open`` can rebuild the stream before its first snapshot."""
        if name in self._streams:
            raise ValueError(f"stream {name!r} already exists")
        if int(shards) < 1:
            raise ValueError(f"need >= 1 shard, got {shards}")
        live = self.live_hosts
        if not live:
            raise RuntimeError("no live hosts")
        wal = ckpt_dir = None
        if self.durability_dir is not None:
            ckpt_dir, wal_path, cfg_path = self._stream_paths(name)
            os.makedirs(os.path.dirname(cfg_path), exist_ok=True)
            with open(cfg_path, "w") as f:
                json.dump({"multisketch_spec": spec_to_meta(spec),
                           "shards": int(shards),
                           "hosts": list(live),
                           "engine_kw": {k: v for k, v in engine_kw.items()
                                         if k != "use_kernels"}}, f)
                f.flush()
                os.fsync(f.fileno())
            wal = WriteAheadLog(wal_path)
        st = _ShardedStream(name, spec, shards, engine_kw, wal, ckpt_dir,
                            initial_hosts=live)
        self._streams[name] = st
        return tuple(st.placement)

    @classmethod
    def open(cls, durability_dir: str, hosts: Optional[Sequence[int]] = None,
             **kw) -> "ShardedEnginePool":
        """Recover a pool from its durability directory: the host group
        comes from hosts.json (or ``hosts``), then every stream replays
        checkpoint + WAL tail — data records, GC markers and REBALANCE
        markers in seq order — landing in the identical post-move layout
        the crashed pool had."""
        if hosts is None:
            with open(os.path.join(durability_dir, "hosts.json")) as f:
                hosts = json.load(f)["hosts"]
        pool = cls(hosts=hosts, durability_dir=durability_dir, **kw)
        for name in sorted(os.listdir(durability_dir)):
            if os.path.isfile(os.path.join(durability_dir, name,
                                           "stream.json")):
                pool.restore_stream(name)
        return pool

    def restore_stream(self, name: str) -> Tuple[int, ...]:
        """Restore one stream and distribute its shards to the replayed
        placement's owners. A shard whose replayed owner is dead/absent
        stays undistributed (its data is only in the WAL): queries
        degrade until ``rebalance`` re-partitions and rebuilds it."""
        if self.durability_dir is None:
            raise ValueError("pool has no durability_dir")
        ckpt_dir, wal_path, cfg_path = self._stream_paths(name)
        with open(cfg_path) as f:
            cfg = json.load(f)
        st = _ShardedStream(name, spec_from_meta(cfg["multisketch_spec"]),
                            int(cfg["shards"]), cfg.get("engine_kw", {}),
                            WriteAheadLog(wal_path), ckpt_dir,
                            initial_hosts=cfg["hosts"])
        sub, seq, placement = self._replay_substrate(st)
        st.placement = list(placement)
        for s in range(st.shards):
            h = self._hosts.get(st.placement[s])
            if h is not None and h.alive and sub.shard_live(s):
                self._host_engine(st, h).set_shard(s, sub.shard_slab(s))
        st.ingest_seq = st.applied_seq = seq
        self._streams[name] = st
        return tuple(st.placement)

    def close(self):
        for st in self._streams.values():
            if st.wal is not None:
                st.wal.close()

    # -- recovery substrate --------------------------------------------------
    def _replay_substrate(self, st: _ShardedStream
                          ) -> Tuple[SegmentQueryEngine, int, List[int]]:
        """Rebuild the stream's GLOBAL state on one full-width substrate
        engine: newest intact checkpoint (falling back across corrupt
        steps) + WAL-tail replay, dispatching on the shard tag (>= 0
        data, GC_SHARD, REBALANCE_SHARD). Deterministic folds + recorded
        markers make the result bit-identical to a never-failed engine
        over the same records. Returns (engine, last_seq, placement)."""
        applied = 0
        engine = None
        placement = compute_placement(st.shards, st.initial_hosts)
        if st.ckpt_dir is not None:
            _fault_point("ckpt_restore", st.name)
            try:
                engine, extra = SegmentQueryEngine.from_checkpoint(
                    st.ckpt_dir, return_meta=True)
                applied = int(extra.get("pool_applied_seq", 0))
                pl = extra.get("placement")
                if pl is not None:
                    placement = [int(x) for x in pl]
            except FileNotFoundError:
                pass                   # pre-first-snapshot: replay-only
        if engine is None:
            engine = SegmentQueryEngine(st.spec, shards=st.shards,
                                        **st.engine_kw)
        seq = applied
        if st.wal is not None:
            _fault_point("wal_replay", st.name)
            for rec in st.wal.replay(min_seq_exclusive=applied):
                if rec.shard == GC_SHARD:
                    engine.gc_apply([int(x) for x in rec.keys])
                elif rec.shard == REBALANCE_SHARD:
                    # the RECORDED re-partition, not a recomputation: the
                    # placement chain replays exactly as it was decided
                    placement = [int(x) for x in rec.keys]
                else:
                    engine.absorb(rec.keys, rec.weights, rec.active,
                                  shard=rec.shard)
                seq = rec.seq
        return engine, seq, placement

    def _rebuild_shards(self, st: _ShardedStream, shard_ids
                        ) -> Dict[int, Tuple[MultiSketch, bool]]:
        """Bit-exact slabs for shards whose owner died: full substrate
        replay (checkpoint + WAL tail), then extract the requested
        shards. Replaying EVERYTHING (not just the moved shards) keeps
        adopted GC markers correct — a GC merge moves data across shard
        indices, so a filtered replay could miss contributions."""
        sub, _, _ = self._replay_substrate(st)
        return {int(s): (sub.shard_slab(int(s)), sub.shard_live(int(s)))
                for s in shard_ids}

    # -- ingest (fan-out to owner hosts) ------------------------------------
    def absorb(self, name: str, keys, weights, shard: int = 0
               ) -> AbsorbReceipt:
        """Ingest one chunk, routed to its shard's owner host. Same
        durability contract as ``EnginePool.absorb``: quarantine -> WAL
        append (fsync) -> owner fold with retries. A chunk whose owner is
        dead (or whose fold fails) is still DURABLE and counted in
        ``ingest_seq``; it waits in the pending backlog and queries show
        the exact lag until a rebalance (or the host's op succeeding)
        drains it. Backlog past ``pending_limit`` sheds with
        :class:`RejectedError` — the rejected chunk was never ack'd."""
        st = self._stream(name)
        if not (0 <= int(shard) < st.shards):
            raise ValueError(
                f"shard must be in [0, {st.shards}), got {shard}")
        k, w, act, n_bad = quarantine_chunk(keys, weights)
        st.quarantined += n_bad
        accepted = int(np.count_nonzero(act))
        if accepted == 0:
            return AbsorbReceipt(0, n_bad, applied=True,
                                 durable=st.wal is not None,
                                 seq=st.ingest_seq)
        if len(st.pending) >= self.pending_limit:
            raise RejectedError(
                f"stream {name!r} fold backlog full "
                f"({len(st.pending)} chunks)")
        seq = st.ingest_seq + 1
        if st.wal is not None:
            _fault_point("wal_append", name)
            st.wal.append(seq, shard, k, w, act.astype(np.uint8))
        st.ingest_seq = seq
        st.pending.append((seq, int(shard), k, w, act))
        applied = self._drain_pending(st)
        if applied:
            self._maybe_snapshot(st)
        return AbsorbReceipt(accepted, n_bad, applied=applied,
                             durable=st.wal is not None, seq=seq)

    def _drain_pending(self, st: _ShardedStream) -> bool:
        """Fold the backlog in sequence order onto owner hosts; True iff
        fully applied. Stops (without consuming) at the first chunk whose
        owner is dead — the WAL keeps it recoverable, and a rebalance
        replays it onto the new owner."""
        touched = set()
        while st.pending:
            seq, shard, k, w, act = st.pending[0]
            hid = st.placement[shard]
            # host-kill schedules fire here (deterministic op index)
            _fault_point("host_op", f"{st.name}@h{hid}")
            host = self._hosts.get(hid)
            if host is None or not host.alive:
                break
            try:
                self._retry(lambda: self._fold_one(st, host, shard,
                                                   k, w, act))
            except Exception:
                break
            st.pending.popleft()
            st.applied_seq = seq
            st.folds_since_snapshot += 1
            touched.add(hid)
        for hid in touched:
            eng = self._hosts[hid].engines.get(st.name)
            if eng is not None:
                # charge device work to the ingest path (zero-merge reads)
                eng.drain()
        return not st.pending

    def _fold_one(self, st: _ShardedStream, host: _Host, shard, k, w, act):
        _fault_point("absorb_fold", st.name)
        self._host_engine(st, host).absorb(k, w, act, shard=shard)

    def _retry(self, fn):
        return _retry_loop(fn, retries=self.retries,
                           backoff_base=self.backoff_base,
                           backoff_cap=self.backoff_cap,
                           rng=self._rng, sleep=self._sleep)

    # -- durability snapshots ------------------------------------------------
    def _maybe_snapshot(self, st: _ShardedStream):
        if (self.snapshot_every and st.ckpt_dir is not None
                and st.folds_since_snapshot >= self.snapshot_every):
            try:
                self.snapshot(st.name)
            except Exception:
                pass                   # WAL still covers everything

    def snapshot(self, name: str):
        """Checkpoint the stream's GLOBAL state: gather every live
        shard's slab from its owner onto a full-width substrate and save
        it (atomic, crc'd) stamping the applied sequence + placement,
        then prune the WAL to the oldest retained snapshot. Requires
        every shard's owner alive (a dead owner's current slab exists
        only in the WAL — rebalance first)."""
        st = self._stream(name)
        if st.ckpt_dir is None:
            raise ValueError(f"stream {name!r} is not durable")
        for s in range(st.shards):
            if not self._host_alive(st.placement[s]):
                raise HostDownError(
                    f"cannot snapshot {name!r}: owner host "
                    f"{st.placement[s]} of shard {s} is down")
        _fault_point("ckpt_save", name)
        sub = SegmentQueryEngine(st.spec, shards=st.shards, **st.engine_kw)
        for s in range(st.shards):
            eng = self._host_engine(st, self._hosts[st.placement[s]])
            if eng.shard_live(s):
                sub.set_shard(s, eng.shard_slab(s))
        sub.save_checkpoint(
            st.ckpt_dir,
            extra_meta={"pool_applied_seq": st.applied_seq,
                        "placement": [int(x) for x in st.placement]})
        st.folds_since_snapshot = 0
        st.snapshot_seqs.append(st.applied_seq)
        if (st.wal is not None
                and len(st.snapshot_seqs) >= self.keep_snapshots):
            st.wal.prune(st.snapshot_seqs[-self.keep_snapshots])

    # -- reads (cross-host merge + replica degradation) ----------------------
    def query(self, name: str, fs: Optional[Sequence[StatFn]] = None,
              predicates=EVERYTHING, timeout: Optional[float] = None
              ) -> Response:
        """Answer a segment-query batch from the global union.

        FRESH path: one stacked re-selection over the live owners' merged
        slabs (memoized on placement + engine epochs — steady-state reads
        pay zero merge work), bit-identical to a single-host union engine
        by threshold closure. On failure (owner host down, injected
        fault): the newest surviving replica serves at STALE with the
        exact chunk lag; REJECTED only when no replica survives. Every
        degraded answer is LABELED — never wrong, occasionally stale."""
        st = self._stream(name)
        fs = (tuple(f for f, _ in st.spec.objectives) if fs is None
              else tuple(fs))
        table = np.asarray(encode_predicates(predicates), np.int32)
        deadline = (None if timeout is None
                    else self._clock() + timeout)
        # >= : timeout=0 (or an elapsed budget) sheds, never serves late
        if deadline is not None and self._clock() >= deadline:
            return Response(REJECTED, error="deadline")
        if st.pending:
            self._drain_pending(st)    # opportunistic catch-up
        err = None
        try:
            slab = self._retry(lambda: self._cross_merged(st))
            vals = multisketch_query_many(
                slab, fs, table, b_quantum=st.b_quantum,
                use_kernels=st.use_kernels)
            lag = st.ingest_seq - st.applied_seq
            self._replicate(st, slab)
            return Response(FRESH if lag == 0 else STALE, vals,
                            epoch_lag=lag,
                            overflow=bool(multisketch_overflow(slab)))
        except Exception as e:
            err = f"{type(e).__name__}: {e}"
        rep = self._newest_replica(st)
        if rep is not None:
            rep_seq, slab = rep
            vals = multisketch_query_many(
                slab, fs, table, b_quantum=st.b_quantum,
                use_kernels=st.use_kernels)
            return Response(STALE, vals,
                            epoch_lag=st.ingest_seq - rep_seq,
                            overflow=bool(multisketch_overflow(slab)),
                            error=err)
        return Response(REJECTED, error=err or "no surviving replica")

    def _cross_merged(self, st: _ShardedStream) -> MultiSketch:
        """The global merged slab: stacked re-selection over every owner
        host's merged slab (launch.summary.merge_host_slabs — the step-3
        path). Raises :class:`HostDownError` when any owner is dead: a
        partial union would be silently WRONG, not stale, so the caller
        must degrade to a labeled replica instead."""
        _fault_point("query_merge", st.name)
        owners = sorted({st.placement[s] for s in range(st.shards)})
        for hid in owners:
            if not self._host_alive(hid):
                raise HostDownError(
                    f"host {hid} down (owns shards of {st.name!r})")
        key = (st.placement_version,
               tuple((hid, self._host_engine(st, self._hosts[hid]).epoch)
                     for hid in owners))
        if st.cross_cache is not None and st.cross_cache[0] == key:
            return st.cross_cache[1]
        slabs = [self._host_engine(st, self._hosts[hid]).merged
                 for hid in owners]
        merged = merge_host_slabs(st.spec, slabs,
                                  use_kernels=st.use_kernels)
        st.cross_merges += 1
        st.cross_cache = (key, merged)
        return merged

    def _replica_hosts(self, st: _ShardedStream) -> List[int]:
        """Primary + one FOLLOWER for the stream's last-good slab —
        rendezvous-ranked over the live hosts by stream name, so the pair
        is deterministic yet spreads across streams. Keeping the copy on
        TWO hosts is what lets a read survive the primary's loss."""
        ranked = sorted(
            self.live_hosts,
            key=lambda h: zlib.crc32(f"{st.name}@{h}".encode()),
            reverse=True)
        return ranked[:2]

    def _replicate(self, st: _ShardedStream, slab: MultiSketch):
        for hid in self._replica_hosts(st):
            self._hosts[hid].replicas[st.name] = (st.applied_seq, slab)

    def _newest_replica(self, st: _ShardedStream
                        ) -> Optional[Tuple[int, MultiSketch]]:
        best = None
        for h in self._hosts.values():
            if h.alive and st.name in h.replicas:
                seq, slab = h.replicas[st.name]
                if best is None or seq > best[0]:
                    best = (seq, slab)
        return best

    # -- membership change (rebalance + REBALANCE marker) --------------------
    def rebalance(self, name: Optional[str] = None,
                  exclude: Sequence[int] = ()) -> Dict[str, dict]:
        """Re-partition stream shards over the current live hosts (minus
        ``exclude``), per-stream: live->live moves are slab hand-offs
        (set_shard copy, clear_shard release); shards stranded on a DEAD
        host are rebuilt bit-exactly from checkpoint + WAL tail. Each
        changed stream then appends a REBALANCE marker recording the new
        placement — apply-then-append, so a crash between the two loses
        only the directive: recovery replays the PRE-move placement whose
        merged union (hence every answer) is identical."""
        names = [name] if name is not None else sorted(self._streams)
        return {nm: self._rebalance_stream(self._streams[nm], exclude)
                for nm in names}

    def _rebalance_stream(self, st: _ShardedStream,
                          exclude: Sequence[int]) -> dict:
        targets = [h for h in self.live_hosts if h not in set(exclude)]
        if not targets:
            raise RuntimeError("no live hosts to rebalance onto")
        new_place = compute_placement(st.shards, targets)
        moved = {s: (st.placement[s], new_place[s])
                 for s in range(st.shards)
                 if st.placement[s] != new_place[s]}
        if not moved:
            return {"moved": {}, "placement": tuple(st.placement),
                    "marker_seq": None, "error": None}
        dead_src = sorted({s for s, (o, _) in moved.items()
                           if not self._host_alive(o)})
        rebuilt = self._rebuild_shards(st, dead_src) if dead_src else {}
        for s, (o, n) in sorted(moved.items()):
            teng = self._host_engine(st, self._hosts[n])
            if s in rebuilt:
                slab, live = rebuilt[s]
                if live:
                    teng.set_shard(s, slab)
            else:
                seng = self._host_engine(st, self._hosts[o])
                if seng.shard_live(s):
                    teng.set_shard(s, seng.shard_slab(s))
                seng.clear_shard(s)
        st.placement = list(new_place)
        st.placement_version += 1
        st.cross_cache = None
        if dead_src:
            # the rebuild REPLAYED every WAL'd record of those shards —
            # pending entries for them are already in the new owner's slab
            covered = set(dead_src)
            st.pending = deque(p for p in st.pending
                               if p[1] not in covered)
            st.applied_seq = (st.pending[0][0] - 1 if st.pending
                              else st.ingest_seq)
        self._drain_pending(st)
        err = None
        marker_seq = st.ingest_seq + 1
        if st.wal is not None:
            try:
                _fault_point("wal_append", st.name)
                st.wal.append(marker_seq, REBALANCE_SHARD,
                              np.asarray(new_place, np.int32),
                              np.zeros(st.shards, np.float32),
                              np.ones(st.shards, np.uint8))
            except Exception as e:
                # moves applied but the marker is lost: recovery replays
                # the pre-move placement — same union, identical answers
                err = (f"rebalance marker not durable: "
                       f"{type(e).__name__}: {e}")
        st.ingest_seq = marker_seq
        if not st.pending:
            st.applied_seq = marker_seq
        return {"moved": moved, "placement": tuple(new_place),
                "marker_seq": marker_seq, "error": err}

    # -- health --------------------------------------------------------------
    def _stream(self, name: str) -> _ShardedStream:
        try:
            return self._streams[name]
        except KeyError:
            raise KeyError(f"unknown stream {name!r}") from None

    @property
    def streams(self):
        return tuple(self._streams)

    def placement(self, name: str) -> Tuple[int, ...]:
        return tuple(self._stream(name).placement)

    def stats(self, name: str) -> dict:
        """Health snapshot of one stream: sequence frontier, placement,
        owner liveness, cross-merge accounting."""
        st = self._stream(name)
        owners = sorted({st.placement[s] for s in range(st.shards)})
        return {"ingest_seq": st.ingest_seq,
                "applied_seq": st.applied_seq,
                "epoch_lag": st.ingest_seq - st.applied_seq,
                "pending": len(st.pending),
                "quarantined": st.quarantined,
                "placement": tuple(st.placement),
                "placement_version": st.placement_version,
                "owners": tuple(owners),
                "owners_alive": all(self._host_alive(h) for h in owners),
                "cross_merges": st.cross_merges,
                "replica_hosts": tuple(self._replica_hosts(st))
                if self.live_hosts else ()}

    def host_stats(self) -> Dict[int, dict]:
        """Per-host gauges under the engine's ``merge_stats`` wire names
        (summed over the host's resident engines), plus ownership and
        replica counts — the scale-out rows telemetry exports next to the
        stream stats (telemetry.stats.collect_host_gauges)."""
        out: Dict[int, dict] = {}
        for hid in sorted(self._hosts):
            h = self._hosts[hid]
            row = {"alive": h.alive, "streams": len(h.engines),
                   "replica_streams": len(h.replicas),
                   "owned_shards": sum(
                       1 for st in self._streams.values()
                       for s in range(st.shards)
                       if st.placement[s] == hid),
                   "live_shards": 0, "bytes_resident": 0, "gc_merges": 0}
            for eng in h.engines.values():
                row["live_shards"] += eng.merge_stats["live_shards"]
                row["bytes_resident"] += eng.merge_stats["bytes_resident"]
                row["gc_merges"] += eng.merge_stats["gc_merges"]
            out[hid] = row
        return out
