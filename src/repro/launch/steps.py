"""Jitted, sharded step functions + abstract input/state builders.

Everything here works both with concrete arrays (training on real devices)
and with ShapeDtypeStructs through .lower()/.compile() (the multi-pod
dry-run) — no device allocation happens at build time.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.multi_sketch import (MultiSketchSpec,
                                     multisketch_absorb_inline)
from repro.models import model as Mod
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.configs.shapes import ShapeConfig
from . import sharding as Sh


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins — never allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct batch for (arch x shape). Train/prefill kinds give the
    full-sequence batch; decode kinds give the per-step token batch."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encoder":
            return {"frames": f((B, S, cfg.d_model), jnp.bfloat16),
                    "labels": f((B, S), jnp.int32)}
        if cfg.family == "vlm":
            Ptok = cfg.frontend_tokens
            return {"tokens": f((B, S - Ptok), jnp.int32),
                    "patches": f((B, Ptok, cfg.d_model), jnp.bfloat16)}
        return {"tokens": f((B, S), jnp.int32)}
    # decode: one new token against a cache of length S
    return {"tokens": f((B,), jnp.int32)}


def abstract_params(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical spec tree) without allocation.

    The spec tree is plain python (tuples of axis names) built during
    tracing, so we capture it via closure instead of returning it through
    eval_shape.
    """
    captured = {}

    def build():
        p, s = Mod.init_model(jax.random.PRNGKey(0), cfg)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(build)
    return shapes, captured["specs"]


def abstract_state(cfg: ModelConfig):
    p_shapes, specs = abstract_params(cfg)
    opt_shapes = jax.eval_shape(adamw.init_opt_state, p_shapes)
    return {"params": p_shapes, "opt": opt_shapes}, specs


def state_shardings(cfg: ModelConfig, mesh):
    state_shapes, specs = abstract_state(cfg)
    psh = Sh.param_shardings(specs, state_shapes["params"], mesh,
                             fsdp=cfg.fsdp)
    rep = Sh.replicated(mesh)
    return {
        "params": psh,
        "opt": {"m": psh, "v": psh, "step": rep},
    }, state_shapes


def cache_abstract(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: Mod.make_cache(cfg, shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# step factories
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig, mesh,
                    grad_transform=None, microbatch: Optional[int] = None,
                    donate: bool = True, shape: Optional[ShapeConfig] = None,
                    compress: Optional[dict] = None,
                    telemetry: Optional[MultiSketchSpec] = None):
    """Returns (jitted_step, state_shardings_tree).

    grad_transform: optional fn(grads, params, step) -> grads applied between
    backward and optimizer.
    microbatch: if set, split the batch into `microbatch` sequential
    accumulation steps (grad accumulation via lax.scan).
    compress: if set (dict of distopt.compression kwargs) and the mesh has a
    "pod" axis, the cross-pod gradient reduction becomes the paper's sampled
    exchange (multi-objective bottom-k sketches over DCN) instead of a dense
    all-reduce.
    telemetry: if set (a MultiSketchSpec), the train state carries a
    device-resident MultiSketch under key "tel" and every step folds the
    per-example loss proxies into it INSIDE the jitted step (donated
    buffers, no host round-trip) — queryable any time via sketch_estimate.
    """
    st_shard, _ = state_shardings(cfg, mesh)
    if telemetry is not None:
        from repro.launch.summary import multisketch_shape
        rep = Sh.replicated(mesh)
        st_shard["tel"] = jax.tree.map(lambda _: rep,
                                       multisketch_shape(telemetry))
    batch_sh = (Sh.batch_shardings(input_specs(cfg, shape), mesh)
                if shape is not None else None)

    def loss_of(params, batch):
        return Mod.loss_fn(params, cfg, batch)

    def compute_grads_once(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params, batch)
        return loss, metrics, grads

    def compute_grads(params, batch):
        """Full-batch grads, with optional microbatch accumulation."""
        if not (microbatch and microbatch > 1):
            return compute_grads_once(params, batch)
        # under compression this runs INSIDE a pod-manual shard_map: the
        # batch is already pod-local, so constrain on "data" only
        baxis = "data" if compress is not None else Sh.batch_pspec(mesh)[0]

        def split(leaf):
            b = leaf.shape[0]
            out = leaf.reshape(microbatch, b // microbatch, *leaf.shape[1:])
            # keep each microbatch sharded on the data axes — without this
            # the reshape decays to replicated and compute is duplicated
            spec = P(*([None, baxis] + [None] * (out.ndim - 2)))
            return jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, spec))
        micro = jax.tree.map(split, batch)

        def acc(carry, mb):
            loss_a, grads_a = carry
            loss, metrics, grads = compute_grads_once(params, mb)
            return (loss_a + loss,
                    jax.tree.map(jnp.add, grads_a, grads)), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), metrics = jax.lax.scan(
            acc, (jnp.float32(0), zeros), micro)
        return (loss / microbatch,
                jax.tree.map(lambda m: m[-1], metrics),
                jax.tree.map(lambda g: g / microbatch, grads))

    compressed = None
    if compress is not None:
        from repro.distopt.compression import compressed_grads_fn
        compressed = compressed_grads_fn(compute_grads, mesh, **compress)

    def step_fn(state, batch):
        params = state["params"]
        if compressed is not None:
            pspecs = jax.tree.map(lambda ns: ns.spec, st_shard["params"])
            loss, metrics, grads = compressed(params, batch,
                                              state["opt"]["step"], pspecs)
        else:
            loss, metrics, grads = compute_grads(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads, params, state["opt"]["step"])

        new_params, new_opt, om = adamw.apply_updates(
            params, grads, state["opt"], opt_cfg)
        new_state = {"params": new_params, "opt": new_opt}
        if telemetry is not None:
            # fold per-example loss proxies keyed step * 2^16 + example. The
            # stride is a CONSTANT (not the batch size) so keys stay unique
            # across a resume with a different --batch; bounds: b <= 65536
            # per step, step < 32768 before int32 wrap (past either, keys
            # collide and the dedup silently merges observations)
            b = jax.tree_util.tree_leaves(batch)[0].shape[0]
            step_id = state["opt"]["step"].astype(jnp.int32)
            tkeys = step_id * jnp.int32(1 << 16) + jnp.arange(b, dtype=jnp.int32)
            new_state["tel"] = multisketch_absorb_inline(
                telemetry, state["tel"], tkeys, jnp.full((b,), loss))
        return (new_state, {"loss": loss, **metrics, **om})

    jitted = jax.jit(
        step_fn,
        in_shardings=(st_shard, batch_sh),
        out_shardings=(st_shard, None),
        donate_argnums=(0,) if donate else ())
    return jitted, st_shard


def make_prefill_step(cfg: ModelConfig, mesh,
                      shape: Optional[ShapeConfig] = None):
    def step_fn(params, batch):
        return Mod.prefill(params, cfg, batch)

    p_shapes, specs = abstract_params(cfg)
    psh = Sh.param_shardings(specs, p_shapes, mesh, fsdp=cfg.fsdp)
    batch_sh = (Sh.batch_shardings(input_specs(cfg, shape), mesh)
                if shape is not None else None)
    cache_sh = (Sh.cache_shardings(
        jax.eval_shape(lambda: Mod.make_cache(
            cfg, shape.global_batch, shape.seq_len)), cfg, mesh)
        if shape is not None and cfg.family != "encoder" else None)
    out_sh = (None, cache_sh) if cache_sh is not None else None
    return jax.jit(step_fn, in_shardings=(psh, batch_sh),
                   out_shardings=out_sh), psh


def make_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    donate: bool = True):
    """Single-token decode step against a seq_len cache."""
    p_shapes, specs = abstract_params(cfg)
    psh = Sh.param_shardings(specs, p_shapes, mesh, fsdp=cfg.fsdp)
    cache_sh = Sh.cache_shardings(cache_abstract(cfg, shape), cfg, mesh)

    def step_fn(params, tokens, cache, index):
        return Mod.serve_step(params, cfg, tokens, cache, index)

    jitted = jax.jit(
        step_fn,
        in_shardings=(psh, Sh.batch_shardings(
            input_specs(cfg, shape)["tokens"], mesh), cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,) if donate else ())
    return jitted, psh, cache_sh
