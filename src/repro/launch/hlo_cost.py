"""Post-optimization HLO cost model with while-loop trip-count accounting.

``compiled.cost_analysis()`` counts while bodies ONCE, which silently
undercounts scanned-layer models by ~num_layers x. This module re-derives
per-device costs from ``compiled.as_text()`` by walking the call graph
(entry -> fusions/calls/whiles) and multiplying while bodies by their
``known_trip_count`` backend config:

  flops      — 2*M*N*K for dot ops, conv FLOPs, ~1/elem for elementwise
  hbm_bytes  — sum over materialized (top-level) instructions of
               operand + result buffer bytes (fusion internals excluded:
               a fusion reads its operands and writes its result once)
  coll_bytes — operand bytes of all-reduce / all-gather / reduce-scatter /
               all-to-all / collective-permute (+ async -start variants),
               trip-multiplied
  coll_ops   — instance counts per collective kind

Shapes in post-SPMD HLO are per-device (already partitioned), so every
number reported here is PER DEVICE — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_ELEMENTWISE_FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "log-plus-one", "exponential-minus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cosine", "sine", "negate", "abs",
    "compare", "select", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "atan2", "remainder",
    "erf", "cbrt",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list
    attrs: str
    raw_ops: str = ""


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # %name -> type string


_COMMENT = re.compile(r"/\*.*?\*/")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{$")
_OP_CALL = re.compile(r"([\w\-]+)\(")


def _balanced(s: str, start: int = 0):
    """Return index just past the balanced paren group starting at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _split_top_commas(s: str):
    out, depth, last = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[last:i])
            last = i + 1
    out.append(s[last:])
    return [x.strip() for x in out if x.strip()]


def parse_hlo(text: str) -> dict:
    """Parse computations; return {comp_name: Computation}."""
    comps = {}
    cur = None
    for raw in text.splitlines():
        line = _COMMENT.sub("", raw.rstrip())
        if cur is None:
            stripped = line.strip()
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(1))
                # parameter types from signature: "name: type, name: type"
                for decl in _split_top_commas(m.group(2)):
                    if ":" in decl:
                        nm, ty = decl.split(":", 1)
                        cur.types[nm.strip().lstrip("%")] = ty.strip()
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _NAME_EQ.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # type: balanced-paren tuple or single whitespace-free token
        if rest.startswith("("):
            tend = _balanced(rest, 0)
            type_str = rest[:tend]
            rest = rest[tend:].lstrip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            type_str = rest[:sp]
            rest = rest[sp + 1:].lstrip()
        mo = _OP_CALL.match(rest)
        if not mo:
            continue
        op = mo.group(1)
        oend = _balanced(rest, mo.end() - 1)
        ops_str = rest[mo.end():oend - 1]
        attrs = rest[oend:]
        operands = re.findall(r"%([\w\.\-]+)", ops_str)
        inst = Instr(name, type_str.strip(), op, operands, attrs, ops_str)
        cur.instrs.append(inst)
        cur.types[name] = inst.type_str
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_ops: dict = field(default_factory=dict)
    transcendental: float = 0.0
    coll_bytes_xpod: float = 0.0  # cross-pod (DCN) share of coll_bytes

    def __iadd__(self, o):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.coll_bytes += o.coll_bytes
        self.transcendental += o.transcendental
        self.coll_bytes_xpod += o.coll_bytes_xpod
        for k, v in o.coll_ops.items():
            self.coll_ops[k] = self.coll_ops.get(k, 0) + v
        return self

    def scaled(self, k):
        return Cost(self.flops * k, self.hbm_bytes * k, self.coll_bytes * k,
                    {a: b * k for a, b in self.coll_ops.items()},
                    self.transcendental * k, self.coll_bytes_xpod * k)


_POD_STRIDE = 256  # device ids: pod*256 + data*16 + model on the 2x16x16 mesh


def _groups_cross_pod(attrs: str) -> bool:
    """True if any replica group spans both pods (DCN traffic).

    Handles both explicit ``{{0,256},{1,257},...}`` and iota
    ``[G,S]<=[d0,d1,..]T(perm)`` formats (groups reconstructed exactly).
    """
    m = re.search(r"replica_groups=\{\{([0-9,}{\s]+)\}\}", attrs)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.strip("{}").split(",") if x.strip()]
            if ids and (min(ids) < _POD_STRIDE <= max(ids)):
                return True
        return False
    m = re.search(r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\]"
                  r"(?:T\(([0-9,]+)\))?", attrs)
    if m:
        import numpy as _np
        gshape = [int(x) for x in m.group(1).split(",")]
        src = [int(x) for x in m.group(2).split(",")]
        total = 1
        for d in src:
            total *= d
        if total <= _POD_STRIDE:
            return False
        ids = _np.arange(total).reshape(src)
        if m.group(3):
            ids = ids.transpose([int(x) for x in m.group(3).split(",")])
        rows = ids.reshape(gshape)
        return bool(_np.any((rows.min(axis=1) < _POD_STRIDE)
                            & (rows.max(axis=1) >= _POD_STRIDE)))
    # source_target_pairs (collective-permute)
    m = re.search(r"source_target_pairs=\{([0-9,}{\s]+)\}", attrs)
    if m:
        for pair in m.group(1).split("},{"):
            ids = [int(x) for x in pair.strip("{}").split(",") if x.strip()]
            if len(ids) == 2 and ((ids[0] < _POD_STRIDE) !=
                                  (ids[1] < _POD_STRIDE)):
                return True
    return False


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(inst.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    if not m or not inst.operands:
        return 2.0 * out_elems  # fallback
    lhs_type = comp.types.get(inst.operands[0], "")
    dims = _shape_dims(lhs_type)
    k = 1
    if m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(dims):
                k *= dims[int(d)]
    return 2.0 * out_elems * k


def _conv_flops(inst: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(inst.type_str)
    if len(inst.operands) < 2:
        return 2.0 * out_elems
    ker_dims = _shape_dims(comp.types.get(inst.operands[1], ""))
    ker = 1
    for d in ker_dims:
        ker *= d
    # flops = 2 * output elems * (kernel elems per output feature)
    out_feat = ker_dims[-1] if ker_dims else 1
    return 2.0 * out_elems * ker / max(out_feat, 1)


def _instr_cost(inst: Instr, comp: Computation, comps: dict,
                memo: dict) -> Cost:
    c = Cost()
    op = inst.op
    if op in ("parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "partition-id", "replica-id"):
        return c

    # recursion into called computations
    if op == "fusion" or op == "call" or op == "async-start":
        called = None
        m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", inst.attrs)
        if m and m.group(1) in comps:
            called = comps[m.group(1)]
            sub = _comp_cost(called, comps, memo)
            c.flops += sub.flops
            c.coll_bytes += sub.coll_bytes
            c.transcendental += sub.transcendental
            for k, v in sub.coll_ops.items():
                c.coll_ops[k] = c.coll_ops.get(k, 0) + v
        # materialization: operands read + result written, with aliasing/
        # slicing awareness:
        #  * in-place update fusions (root = dynamic-update-slice) alias
        #    their accumulator operand — count the update slice only;
        #  * operands consumed ONLY via dynamic-slice inside the fusion are
        #    read slice-wise, not whole-buffer (e.g. the per-layer read of
        #    the stacked remat-checkpoint buffer).
        root = called.instrs[-1] if called and called.instrs else None
        dus_root = root is not None and root.op == "dynamic-update-slice"
        if dus_root and len(root.operands) > 1:
            c.hbm_bytes += 2 * _shape_bytes(called.types.get(root.operands[1], ""))
        else:
            c.hbm_bytes += _shape_bytes(inst.type_str)

        sliced_reads = {}
        if called is not None:
            # param index -> param name
            pnames = {}
            for ci in called.instrs:
                if ci.op == "parameter":
                    try:
                        pnames[int(ci.raw_ops.strip())] = ci.name
                    except ValueError:
                        pass
            for idx, pname in pnames.items():
                consumers = [ci for ci in called.instrs
                             if pname in ci.operands and ci.op != "parameter"]
                if consumers and all(ci.op == "dynamic-slice" and
                                     ci.operands and ci.operands[0] == pname
                                     for ci in consumers):
                    sliced_reads[idx] = sum(_shape_bytes(ci.type_str)
                                            for ci in consumers)

        skipped_alias = not dus_root
        for j, o in enumerate(inst.operands):
            ty = comp.types.get(o, "")
            if (not skipped_alias
                    and ty.split("{")[0] == inst.type_str.split("{")[0]):
                skipped_alias = True  # the aliased accumulator buffer
                continue
            if j in sliced_reads:
                c.hbm_bytes += sliced_reads[j]
            else:
                c.hbm_bytes += _shape_bytes(ty)
        return c

    if op == "while":
        m = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
        t = re.search(r'known_trip_count.*?"n":"(\d+)"', inst.attrs)
        trip = int(t.group(1)) if t else 1
        if m and m.group(1) in comps:
            body = _comp_cost(comps[m.group(1)], comps, memo)
            c += body.scaled(trip)
        cm = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
        if cm and cm.group(1) in comps:
            c += _comp_cost(comps[cm.group(1)], comps, memo).scaled(trip)
        return c

    if op == "conditional":
        # branches are rare in our models; count buffers only
        c.hbm_bytes += _shape_bytes(inst.type_str)
        return c

    base = op.replace("-start", "").replace("-done", "")
    if base in _COLLECTIVES:
        if op.endswith("-done"):
            return c
        ob = sum(_shape_bytes(comp.types.get(o, "")) for o in inst.operands)
        c.coll_bytes += ob
        c.coll_ops[base] = c.coll_ops.get(base, 0) + 1
        c.hbm_bytes += ob + _shape_bytes(inst.type_str)
        if _groups_cross_pod(inst.attrs):
            c.coll_bytes_xpod += ob
        return c

    # compute ops
    if op == "dot":
        c.flops += _dot_flops(inst, comp)
    elif op == "convolution":
        c.flops += _conv_flops(inst, comp)
    elif op in _ELEMENTWISE_FLOP:
        e = _shape_elems(inst.type_str)
        c.flops += e
        if op in ("exponential", "log", "tanh", "logistic", "power", "erf",
                  "rsqrt", "sqrt", "cosine", "sine", "log-plus-one",
                  "exponential-minus-one"):
            c.transcendental += e
    elif op in ("reduce", "reduce-window"):
        c.flops += sum(_shape_elems(comp.types.get(o, ""))
                       for o in inst.operands[:1])

    # materialized buffer traffic (top-level instrs only; this function is
    # only invoked for instrs of materialized computations). Slicing ops
    # touch only the slice, not the whole buffer (aliasing/in-place).
    if op == "dynamic-slice" or op == "slice":
        c.hbm_bytes += 2 * _shape_bytes(inst.type_str)
        return c
    if op == "dynamic-update-slice":
        upd = comp.types.get(inst.operands[1], "") if len(inst.operands) > 1 else ""
        c.hbm_bytes += 2 * _shape_bytes(upd)
        return c
    c.hbm_bytes += _shape_bytes(inst.type_str)
    for o in inst.operands:
        c.hbm_bytes += _shape_bytes(comp.types.get(o, ""))
    return c


def _comp_cost(comp: Computation, comps: dict, memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    total = Cost()
    memo[comp.name] = total  # guard cycles
    for inst in comp.instrs:
        total += _instr_cost(inst, comp, comps, memo)
    memo[comp.name] = total
    return total


def _find_entry(comps: dict, text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def analyze(hlo_text: str) -> dict:
    """Per-device cost dict from post-optimization HLO text."""
    comps = parse_hlo(hlo_text)
    entry = _find_entry(comps, hlo_text)
    # fusion-internal computations must not be double counted as top-level:
    # we only start from entry and recurse, so that's automatic.
    memo = {}
    c = _comp_cost(comps[entry], comps, memo)
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "coll_bytes": c.coll_bytes,
        "coll_bytes_xpod": c.coll_bytes_xpod,
        "coll_ops": dict(c.coll_ops),
        "transcendental": c.transcendental,
        "n_computations": len(comps),
    }
