"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 200 --batch 8 --seq 128 --smoke --ckpt-dir /tmp/ckpt \
        [--resume] [--compress] [--importance-sampling] [--mesh 2x2x2]

Wires together: config registry, synthetic data pipeline (+ optional
multi-objective importance sampling), AdamW, checkpoint manager (atomic,
keep-k, resume-from-latest), telemetry sketches, optional sampled gradient
exchange, and preemption handling (SIGTERM -> checkpoint -> exit 0).
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core import (COUNT, SUM, MultiSketchSpec, multisketch_empty,
                        sketch_estimate, thresh)
from repro.data.pipeline import DataConfig, Loader, SyntheticCorpus
from repro.launch import steps as St
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.models import model as Mod
from repro.optim import adamw


def parse_mesh(spec: str):
    if not spec:
        return make_host_mesh()
    dims = tuple(int(x) for x in spec.split("x"))
    names = {1: ("data",), 2: ("data", "model"),
             3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, names)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="", help="e.g. 2x2x2 (pod,data,model)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="sampled cross-pod gradient exchange")
    ap.add_argument("--importance-sampling", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = parse_mesh(args.mesh)
    opt_cfg = adamw.OptConfig(peak_lr=args.lr, warmup_steps=args.steps // 20 + 1,
                              total_steps=args.steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      n_docs=20_000)
    corpus = SyntheticCorpus(dcfg)
    loader = Loader(corpus, dcfg, importance=args.importance_sampling)
    # device-resident per-step telemetry: folded INSIDE the jitted train
    # step (donated MultiSketch state), merged/queried whenever asked
    tel_spec = MultiSketchSpec(
        objectives=((SUM, 64), (COUNT, 64), (thresh(5.0), 64)), seed=1234)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with mesh_context(mesh):
        step_fn, st_sh = St.make_train_step(
            cfg, opt_cfg, mesh, donate=False,
            microbatch=args.microbatch or None,
            compress=dict(k=256, min_size=65536) if args.compress else None,
            telemetry=tel_spec)

        params, _ = Mod.init_model(jax.random.PRNGKey(args.seed), cfg)
        state = {"params": params, "opt": adamw.init_opt_state(params),
                 "tel": multisketch_empty(tel_spec)}
        state = jax.device_put(state, st_sh)
        start = 0
        if mgr and args.resume:
            restored, rstep = mgr.restore_latest(state, st_sh)
            if restored is None:
                # checkpoints from before the telemetry sketch lack the
                # "tel" arrays — restore params/opt and start telemetry fresh
                core_tpl = {kk: state[kk] for kk in ("params", "opt")}
                core_sh = {kk: st_sh[kk] for kk in ("params", "opt")}
                restored, rstep = mgr.restore_latest(core_tpl, core_sh)
                if restored is not None:
                    restored = {**restored, "tel": state["tel"]}
            if restored is not None:
                state, start = restored, rstep
                print(f"[train] resumed from step {start}")

        # preemption: checkpoint on SIGTERM, exit cleanly (fault tolerance)
        preempted = {"flag": False}

        def _on_sigterm(signum, frame):
            preempted["flag"] = True
        signal.signal(signal.SIGTERM, _on_sigterm)

        t0 = time.time()
        for step in range(start, args.steps):
            raw = loader.batch(step)
            batch = make_batch(cfg, raw, dcfg)
            state, metrics = step_fn(state, batch)
            if (step + 1) % args.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = (time.time() - t0) / max(step - start + 1, 1)
                print(f"step {step+1:5d} loss {loss:8.4f} gnorm {gn:8.3f} "
                      f"{dt*1e3:7.1f} ms/step", flush=True)
            if mgr and ((step + 1) % args.ckpt_every == 0 or preempted["flag"]):
                mgr.save(step + 1, state, blocking=False)
            if preempted["flag"]:
                print(f"[train] preempted at step {step+1}; checkpointed")
                mgr and mgr.wait()
                sys.exit(0)

        if mgr:
            mgr.save(args.steps, state, blocking=True)

        # telemetry demo: the device-resident multi-objective summary
        # answers several f-statistics over the whole training history
        tel = state["tel"]
        print("[telemetry] sketch size:", int(jnp.sum(tel.member)))
        print("[telemetry] est total loss mass:",
              float(sketch_estimate(tel, SUM)))
        print("[telemetry] est #obs with loss>=5:",
              float(sketch_estimate(tel, thresh(5.0))))
    return state


def make_batch(cfg, raw, dcfg):
    toks = jnp.asarray(raw["tokens"])
    if cfg.family == "encoder":
        B, S = toks.shape
        emb = jax.random.normal(jax.random.PRNGKey(0), (B, S, cfg.d_model),
                                jnp.bfloat16)  # stub frontend features
        return {"frames": emb, "labels": toks % cfg.vocab_size}
    if cfg.family == "vlm":
        P = cfg.frontend_tokens
        B, S = toks.shape
        patches = jax.random.normal(jax.random.PRNGKey(1), (B, P, cfg.d_model),
                                    jnp.bfloat16)
        return {"tokens": toks[:, :max(S - P, 8)], "patches": patches}
    return {"tokens": toks}


if __name__ == "__main__":
    main()
