"""Metric-space clustering engine (the second serving tier, paper §7).

The counterpart of ``launch.query.SegmentQueryEngine`` for query-indexed
METRIC objectives: instead of key predicates, a query is a candidate
center set C and the answer is the HT estimate of its clustering cost
Sum_x min_{c in C} d(x,c)^mu (or ball coverage). The engine keeps a
device-RESIDENT sampled point slab:

  * a ``MultiSketch`` over point keys whose weights are the anchor-based
    universal upper-bound probabilities (core.metric_domains) — absorbing
    a chunk is the jit'd donated streaming fold, exact under merge;
  * a coords slab [cap, dim] ALIGNED slot-by-slot with the sketch
    (realigned on device after every fold — one argsort + gather), so the
    fused service-cost kernel (kernels.servicecost) reads coordinates and
    HT weights from the same resident arrays;
  * anchor normalizers frozen at the first chunk, keeping ppswor seeds
    comparable across chunks (coordination under a fixed normalization).

``service_costs`` answers a Q-batch of candidate sets x the slab in ONE
fused launch (Q bucketed to a quantum so jit traces stay bounded).

On top rides the paper's optimization meta-algorithm — compute a sample
once, then optimize over estimated costs:

  * :func:`local_search` — swap-based k-median/k-means local search where
    ALL candidate swaps of a round (1 + k * n_cand sets) are scored by one
    fused Q-batch; pass ``scorer=exact_scorer(X)`` to run the identical
    search against ground-truth costs (the small-instance oracle
    cross-check);
  * :func:`kcenter` — sample-based greedy 2-approx k-center (jit'd
    farthest-point on the member slots) with fused ball-coverage
    validation.
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import (CostTable, ball_query, cost_table,
                              encode_cost_queries, estimate_service_costs,
                              exact_service_costs, pad_cost_table)
from repro.core.funcs import SUM
from repro.core.metric_domains import (anchor_upper_weights,
                                       farthest_point_anchors)
from repro.core.multi_sketch import (MultiSketchSpec, multisketch_absorb,
                                     multisketch_empty, pad_chunk)


def _sorted_lookup(cand_keys, cand_coords, queries):
    """(hit [n] bool, rows [n, dim]) — each query key's coords among the
    candidate (key, coord) rows; the shared sort+searchsorted+gather core
    of every realignment path. Negative query keys never hit."""
    order = jnp.argsort(cand_keys)
    sk = cand_keys[order]
    sc = cand_coords[order]
    pos = jnp.clip(jnp.searchsorted(sk, queries), 0, sk.shape[0] - 1)
    hit = (sk[pos] == queries) & (queries >= 0)
    return hit, sc[pos]


@jax.jit
def _align_coords(new_keys, cand_keys, cand_coords):
    """coords for each slab slot, looked up among candidate (key, coord)
    rows — the device-side realignment after a donated fold."""
    hit, rows = _sorted_lookup(cand_keys, cand_coords, new_keys)
    return jnp.where(hit[:, None], rows, 0.0)


@jax.jit
def _align_coords_delta(new_keys, old_keys, old_coords, chunk_keys,
                        chunk_coords):
    """Delta-aware realignment (the coords twin of the incremental merged-
    slab fold): a slot whose key did not move REUSES its coords row
    directly; only MOVED slots (shifted by compaction or newly inserted
    from the chunk) are re-gathered, and their lookup sorts the old slab
    and the chunk separately ([cap] + [chunk] argsorts instead of one
    [cap+chunk] argsort — the delta is usually much smaller than the
    candidate union). Bit-identical to ``_align_coords`` over the
    concatenated candidates: a re-absorbed key must present the same
    coordinates (ClusterEngine.absorb contract), so source order is free.
    """
    same = (new_keys == old_keys) & (new_keys >= 0)
    moved = jnp.where(same, -1, new_keys)    # unmoved slots skip the gather
    ohit, orows = _sorted_lookup(old_keys, old_coords, moved)
    chit, crows = _sorted_lookup(chunk_keys, chunk_coords, moved)
    looked = jnp.where(ohit[:, None], orows,
                       jnp.where(chit[:, None], crows, 0.0))
    return jnp.where(same[:, None], old_coords, looked)


class ClusterReplica(NamedTuple):
    """Portable snapshot of a ClusterEngine's resident state (deep
    copies): the hand-off unit of the scale-out replication contract —
    see ``ClusterEngine.handoff``."""

    sketch: object            # MultiSketch slab
    coords: object            # [cap, dim] aligned coords
    anchor_coords: object     # frozen anchors (None pre-first-absorb)
    eps: object               # frozen distance regularizer
    norm: object              # frozen per-anchor column sums
    next_key: int
    epoch: int
    config: dict              # constructor kwargs of the source engine


class ClusterEngine:
    """Resident sampled point slab + fused batched service-cost queries.

    ``k`` is the slab sample-size budget (the bottom-k parameter over the
    anchor upper-bound weights); per §7 a target per-query sample of size
    k_q needs k ≈ 2^mu k_q x (anchor overhead). Points are unit-weight
    (clustering over a point set, the paper's metric data model).
    """

    def __init__(self, dim: int, k: int = 64, mu: float = 2.0,
                 n_anchors: int = 8, scheme: str = "ppswor", seed: int = 0,
                 chunk: int = 256, q_quantum: int = 16, q_max: int = 128,
                 use_kernels: Optional[bool] = None):
        self.dim = int(dim)
        self.k = int(k)
        self.mu = float(mu)
        self.n_anchors = int(n_anchors)
        self.chunk = int(chunk)
        self.q_quantum = int(q_quantum)
        self.q_max = int(q_max)   # per-launch Q ceiling (kernel VMEM budget)
        self.use_kernels = use_kernels
        self._handed_out = False  # sample() gave away live slab buffers
        self.spec = MultiSketchSpec(objectives=((SUM, self.k),),
                                    scheme=scheme, seed=seed)
        self._sketch = multisketch_empty(self.spec)
        self._coords = jnp.zeros((self.spec.cap, self.dim), jnp.float32)
        self._anchor_coords = None   # [m, dim] frozen at first absorb
        self._eps = None             # frozen distance regularizer
        self._norm = None            # frozen per-anchor column sums
        self._epoch = 0
        self._next_key = 0
        # natively absorb-time maintained: the fold + coords realignment
        # land in the SAME epoch, so queries never pay merge work — the
        # counters mirror SegmentQueryEngine.merge_stats for telemetry
        self.merge_stats = {"absorb_time": 0, "bytes_resident": 0}
        self._update_gauges()

    @classmethod
    def fit(cls, X, **kw) -> "ClusterEngine":
        """One-shot engine over a full point set."""
        X = np.asarray(X, np.float32)
        eng = cls(dim=X.shape[1], **kw)
        eng.absorb(X)
        return eng

    # -- resident state ----------------------------------------------------
    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def anchors(self):
        return self._anchor_coords

    @property
    def overflow(self) -> bool:
        """Saturation health flag (mirrors SegmentQueryEngine.merge_stats
        ['overflow']): True iff the resident slab is full, i.e. compaction
        may have truncated the sample and cost-estimate cv silently
        degrades — serving tiers should surface it per response."""
        from repro.core.multi_sketch import multisketch_overflow
        return bool(multisketch_overflow(self._sketch))

    def absorb(self, points, keys=None):
        """Fold a chunk of points into the resident slab (donated device
        fold + coords realignment). ``keys`` default to a running global
        index; re-absorbing a key must present the same coordinates."""
        P = jnp.asarray(points, jnp.float32).reshape(-1, self.dim)
        b = P.shape[0]
        if self._anchor_coords is None:
            a_idx, _ = farthest_point_anchors(P, min(self.n_anchors, b))
            self._anchor_coords = P[a_idx]
            _, self._eps, self._norm = anchor_upper_weights(
                P, self._anchor_coords, self.mu)
        v, _, _ = anchor_upper_weights(P, self._anchor_coords, self.mu,
                                       eps=self._eps, norm=self._norm)
        if keys is None:
            keys = np.arange(self._next_key, self._next_key + b,
                             dtype=np.int32)
            self._next_key += b
        else:
            # keep the default-key counter ahead of explicit ids, so a later
            # default-keyed absorb can never alias different points
            keys = np.asarray(keys, np.int32)
            self._next_key = max(self._next_key, int(keys.max()) + 1)
        keys, v, act = pad_chunk(np.asarray(keys, np.int32),
                                 np.asarray(v, np.float32),
                                 np.ones((b,), bool), self.chunk)
        Ppad = jnp.pad(P, ((0, keys.shape[0] - b), (0, 0)))
        # a handed-out sample() may ALIAS the live slab; re-point the engine
        # at fresh buffers first, so the donated fold cannot invalidate the
        # caller's copy (same guard as SegmentQueryEngine.absorb)
        if self._handed_out:
            self._sketch = jax.tree.map(jnp.copy, self._sketch)
            self._handed_out = False
        # the fold donates the resident slab buffers — snapshot the old keys
        # first; old coords are engine-owned and not part of the sketch
        old_keys = jnp.copy(self._sketch.keys)
        old_coords = self._coords
        self._sketch = multisketch_absorb(self._sketch, keys, v, act,
                                          spec=self.spec,
                                          use_kernels=self.use_kernels)
        self._coords = _align_coords_delta(
            self._sketch.keys, old_keys, old_coords,
            jnp.asarray(keys, jnp.int32), Ppad)
        self._epoch += 1
        self.merge_stats["absorb_time"] += 1
        self._update_gauges()

    def _update_gauges(self):
        """Device residency gauge (host-side, no sync): slab + coords."""
        self.merge_stats["bytes_resident"] = (
            sum(int(getattr(x, "nbytes", 0)) for x in self._sketch)
            + int(getattr(self._coords, "nbytes", 0)))

    def sample(self):
        """(coords [cap, dim], probs [cap], member [cap]) — the resident
        slab the fused kernel consumes. The arrays stay valid across later
        ``absorb`` calls (the next fold re-points the engine instead of
        donating the handed-out buffers)."""
        self._handed_out = True
        return self._coords, self._sketch.probs, self._sketch.member

    def total_count(self) -> float:
        """HT estimate of the number of absorbed points."""
        return float(jnp.sum(jnp.where(
            self._sketch.member,
            1.0 / jnp.maximum(self._sketch.probs, 1e-30), 0.0)))

    # -- replica hand-off (scale-out follower promotion) ---------------------
    def handoff(self) -> "ClusterReplica":
        """Deep-copied portable replica of the resident state — the
        cluster tier's leg of the scale-out replication contract
        (launch.pool.ShardedEnginePool): ship it to a follower host and
        ``from_handoff`` promotes it to a serving engine.

        The FROZEN anchor normalizers (anchor coords, eps, per-anchor
        column sums) ride along with the slab: they are what keep ppswor
        seeds comparable across chunks, so a follower promoted WITHOUT
        them would re-freeze its own normalization on its first chunk and
        silently break sample coordination (arXiv 0906.4560) with every
        other replica of this stream. With them, the promoted engine
        serves bit-identical ``service_costs`` AND keeps absorbing
        bit-identically to the source."""
        cp = lambda x: None if x is None else jnp.copy(x)  # noqa: E731
        return ClusterReplica(
            sketch=jax.tree.map(jnp.copy, self._sketch),
            coords=jnp.copy(self._coords),
            anchor_coords=cp(self._anchor_coords),
            eps=cp(self._eps), norm=cp(self._norm),
            next_key=self._next_key, epoch=self._epoch,
            config={"dim": self.dim, "k": self.k, "mu": self.mu,
                    "n_anchors": self.n_anchors,
                    "scheme": self.spec.scheme, "seed": self.spec.seed,
                    "chunk": self.chunk, "q_quantum": self.q_quantum,
                    "q_max": self.q_max})

    @classmethod
    def from_handoff(cls, replica: "ClusterReplica",
                     use_kernels: Optional[bool] = None) -> "ClusterEngine":
        """Promote a handed-off replica to a serving engine (follower
        promotion). See ``handoff`` for the coordination contract."""
        eng = cls(use_kernels=use_kernels, **replica.config)
        eng._sketch = jax.tree.map(jnp.copy, replica.sketch)
        eng._coords = jnp.copy(replica.coords)
        cp = lambda x: None if x is None else jnp.copy(x)  # noqa: E731
        eng._anchor_coords = cp(replica.anchor_coords)
        eng._eps = cp(replica.eps)
        eng._norm = cp(replica.norm)
        eng._next_key = int(replica.next_key)
        eng._epoch = int(replica.epoch)
        eng._update_gauges()
        return eng

    # -- fused batched queries ---------------------------------------------
    def service_costs(self, queries) -> np.ndarray:
        """HT clustering-cost / ball-density estimates for a Q-batch of
        service-cost queries -> float numpy [Q]. ONE fused launch over the
        slab per ``q_max`` rows regardless of Cmax (kernels.servicecost —
        its [Q*Cmax, 128] distance block must fit VMEM, so oversize batches
        are split); Q pads to ``q_quantum`` with null rows so same-bucket
        batches share one compiled executable."""
        table = encode_cost_queries(queries)
        table = CostTable(*(np.asarray(x) for x in table))
        q = table.mu.shape[0]
        out = np.empty((q,), np.float32)
        for s in range(0, q, self.q_max):
            part = CostTable(*(x[s:s + self.q_max] for x in table))
            qp = part.mu.shape[0]
            qpad = max(self.q_quantum,
                       -(-qp // self.q_quantum) * self.q_quantum)
            est = estimate_service_costs(
                self._coords, self._sketch.probs, self._sketch.member,
                pad_cost_table(part, qpad), use_kernels=self.use_kernels)
            out[s:s + qp] = np.asarray(est)[:qp]
        return out

    def clustering_cost(self, centers, mu: Optional[float] = None) -> float:
        """Estimated Sum_x min_{c in centers} d(x,c)^mu for ONE set."""
        from repro.core.costs import cost_query
        return float(self.service_costs(
            cost_query(centers, self.mu if mu is None else mu))[0])

    def ball_density(self, center, r: float) -> float:
        """Estimated |{x : d(x, center-set) <= r}| for ONE set."""
        return float(self.service_costs(ball_query(center, r))[0])


# ---------------------------------------------------------------------------
# the optimization meta-algorithm (sample once, optimize over estimates)
# ---------------------------------------------------------------------------

class ClusterResult(NamedTuple):
    centers: np.ndarray     # [k, dim]
    est_cost: float         # scorer cost of the returned set
    history: List[float]    # accepted cost per round (history[0] = init)
    rounds: int             # swap rounds taken


def exact_scorer(X, point_weights=None) -> Callable[[CostTable], np.ndarray]:
    """Ground-truth scorer over the FULL point set — the oracle the
    sample-based search is cross-checked against on small instances."""
    X = jnp.asarray(X, jnp.float32)

    def score(table: CostTable) -> np.ndarray:
        return np.asarray(exact_service_costs(X, table,
                                              point_weights=point_weights))
    return score


def _candidate_pool(engine: ClusterEngine, n_cand: int) -> np.ndarray:
    """Deterministic candidate center locations: member slots strided
    evenly across the slab. Slab order is retention priority (sampling
    weight desc); the anchor upper-bound weights grow with distance from
    the anchors, so a PREFIX would be all outliers — the stride covers the
    whole weight range, cluster cores included."""
    # private reads (host copies only) — don't trip the hand-out guard
    cand = np.asarray(engine._coords)[np.asarray(engine._sketch.member)]
    m = cand.shape[0]
    if m == 0:
        raise ValueError("empty sample — absorb points first")
    if m <= n_cand:
        return cand
    return cand[np.unique(np.linspace(0, m - 1, n_cand).astype(int))]


def local_search(engine: ClusterEngine, k: int, mu: Optional[float] = None,
                 rounds: int = 16, n_cand: int = 32, tol: float = 1e-3,
                 scorer: Optional[Callable] = None) -> ClusterResult:
    """Sample-based swap local search for k-median (mu=1) / k-means (mu=2).

    Candidates are the engine's member slots; every round scores the
    current set plus ALL k x n_cand single swaps as ONE service-cost
    Q-batch (one fused launch via the engine scorer), accepts the best
    improving swap, and stops when no swap improves by ``tol``
    relatively. ``scorer`` defaults to the engine's fused HT estimator;
    pass :func:`exact_scorer` to run the identical search on ground-truth
    costs.
    """
    mu = engine.mu if mu is None else float(mu)
    if scorer is None:
        scorer = engine.service_costs
    cand = _candidate_pool(engine, n_cand)
    ncand = cand.shape[0]
    k = min(k, ncand)
    # deterministic k-center init over the candidate pool
    init_idx, _ = farthest_point_anchors(jnp.asarray(cand), k)
    cur = np.asarray(cand)[np.asarray(init_idx)]              # [k, dim]

    history = [float(np.asarray(scorer(cost_table(cur[None], mu)))[0])]
    for _ in range(rounds):
        # row 0: current set; row 1 + i*ncand + j: swap center i -> cand j
        sets = np.broadcast_to(cur, (k * ncand, k, cur.shape[1])).copy()
        sets = sets.reshape(k, ncand, k, -1)
        for i in range(k):
            sets[i, :, i, :] = cand
        batch = np.concatenate([cur[None], sets.reshape(k * ncand, k, -1)])
        scores = np.asarray(scorer(cost_table(batch, mu)))
        best = int(np.argmin(scores[1:])) + 1
        if scores[best] < scores[0] * (1.0 - tol):
            i, j = divmod(best - 1, ncand)
            cur = cur.copy()
            cur[i] = cand[j]
            history.append(float(scores[best]))
        else:
            break
    return ClusterResult(centers=cur, est_cost=history[-1],
                         history=history, rounds=len(history) - 1)


class KCenterResult(NamedTuple):
    centers: np.ndarray   # [k, dim]
    radius: float         # max sample-point distance to the centers
    coverage_est: float   # HT estimate of points within ``radius``
    total_est: float      # HT estimate of |X| (coverage should match)


def kcenter(engine: ClusterEngine, k: int) -> KCenterResult:
    """Sample-based greedy k-center (2-approx farthest-point on the member
    slots, one jit'd fori_loop) + fused ball-coverage validation: at the
    returned radius the estimated coverage should match the estimated
    total count (every point served within ``radius``)."""
    pts = jnp.asarray(
        np.asarray(engine._coords)[np.asarray(engine._sketch.member)])
    k = min(k, pts.shape[0])
    idx, d_min = farthest_point_anchors(pts, k)
    centers = np.asarray(pts[idx])
    radius = float(jnp.max(d_min))
    cov = engine.ball_density(centers, radius * (1 + 1e-5))
    return KCenterResult(centers=centers, radius=radius, coverage_est=cov,
                         total_est=engine.total_count())
