"""Sharded MultiSketch construction (paper §3.3 composability, on a mesh).

The distributed build of a multi-objective summary over data sharded along
a mesh axis is three steps, all device-side:

  1. shard_map local build — every device runs the one-shot selection over
     ITS shard only (O(n/m) work, no communication);
  2. all_gather of the fixed-capacity wire slabs — the ONLY collective,
     |F|-independent byte count c * (slots) per device pair;
  3. one batched re-selection over the m * c gathered slots
     (multisketch_merge_stacked) — exact by the threshold-closure merge
     invariant (core.multi_sketch), so the result is bit-identical to a
     one-shot build over the full data.

Because step 3 runs replicated on every device, the merged sketch comes
back un-sharded and immediately queryable. The serving tier
(launch.query.SegmentQueryEngine) instead keeps step 3 LAZY:
``sharded_multisketch_shards`` stops after step 1 and returns the stacked
per-shard slabs, which the engine holds resident and merges on demand
(memoized per absorb epoch) — the eager replicated re-selection here is
for build-then-broadcast pipelines, the engine for query serving.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.multi_sketch import (MultiSketch, MultiSketchSpec,
                                     multisketch_build,
                                     multisketch_finalize,
                                     multisketch_merge_stacked)
from repro.launch.mesh import shard_map_compat


def sharded_multisketch(spec: MultiSketchSpec, mesh, keys, weights,
                        active=None, axis: str = "data") -> MultiSketch:
    """Build S^(F) ∪ Z of globally-sharded data: local build -> all_gather
    slabs -> one re-selection. Exact (same member set/probs/taus as a
    one-shot build over the unsharded data).

    keys/weights/active are global arrays sharded (or shardable) along
    ``axis``; their length must be a multiple of the axis size. Returns a
    replicated MultiSketch.
    """
    keys = jnp.asarray(keys, jnp.int32)
    weights = jnp.asarray(weights, jnp.float32)
    active = (jnp.ones(keys.shape, bool) if active is None
              else jnp.asarray(active, bool))

    def local(k, w, a):
        sk = multisketch_build(spec, k, w, a, use_kernels=False)
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis), sk)
        return multisketch_merge_stacked(spec, MultiSketch(*gathered),
                                         use_kernels=False)

    # fully manual (all axes): the off-``axis`` axes just see replicated
    # data, and legacy-jax shard_map needs no auto-axis support that way
    fn = shard_map_compat(
        local, mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=jax.tree.map(lambda _: P(), multisketch_shape(spec)))
    # re-finalize at host level: the in-trace finalize inlined into the
    # shard_map program, and canonical prob bits require the one
    # fixed-shape finalizer program (core.multi_sketch)
    return multisketch_finalize(jax.jit(fn)(keys, weights, active),
                                spec=spec)


def sharded_multisketch_shards(spec: MultiSketchSpec, mesh, keys, weights,
                               active=None, axis: str = "data"
                               ) -> MultiSketch:
    """Step 1 only: per-device local builds, returned as STACKED slabs
    (leaves [m, ...], one row per device along ``axis``) with no gather and
    no re-selection — the resident state of the lazy serving tier
    (launch.query.SegmentQueryEngine.load_stacked). Exactness of any later
    merge over these rows is the threshold-closure invariant; merging all
    m rows reproduces ``sharded_multisketch`` bit-identically.
    """
    keys = jnp.asarray(keys, jnp.int32)
    weights = jnp.asarray(weights, jnp.float32)
    active = (jnp.ones(keys.shape, bool) if active is None
              else jnp.asarray(active, bool))

    def local(k, w, a):
        sk = multisketch_build(spec, k, w, a, use_kernels=False)
        return jax.tree.map(lambda x: x[None], sk)  # [1, ...] rows to stack

    fn = shard_map_compat(
        local, mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=jax.tree.map(lambda _: P(axis), multisketch_shape(spec)))
    return jax.jit(fn)(keys, weights, active)


def merge_host_slabs(spec: MultiSketchSpec, slabs,
                     use_kernels: Optional[bool] = None) -> MultiSketch:
    """Step 3 for HOST-level slabs: one stacked re-selection over a list
    of already-merged per-host slabs — the cross-host read path of the
    scale-out pool (launch.pool.ShardedEnginePool).

    Exactness is the same threshold-closure argument as the mesh build
    above: each host's merged slab is S^(F) ∪ Z of that host's shard
    union, and one re-selection over the stacked host slabs recovers the
    sample of the GLOBAL union (paper §3.3 — composability is transitive
    through intermediate merges). Bit-identity with a single-host engine
    over the same data holds because this routes through the engine's own
    fold family (``launch.query._full_remerge``: the stacked delta fold
    into a fresh empty slab + the canonical fixed-shape finalizer), so no
    separately-jitted program can disagree in the last ulp of ``probs``.
    """
    slabs = list(slabs)
    if not slabs:
        raise ValueError("merge_host_slabs needs >= 1 host slab")
    if len(slabs) == 1:
        return slabs[0]
    from repro.launch.query import _full_remerge
    return _full_remerge(slabs, spec=spec, use_kernels=use_kernels)


def multisketch_shape(spec: MultiSketchSpec) -> MultiSketch:
    """ShapeDtypeStruct pytree of a sketch (for out_specs/eval_shape)."""
    c, nf = spec.cap, spec.nf
    f = jax.ShapeDtypeStruct
    return MultiSketch(
        keys=f((c,), jnp.int32), weights=f((c,), jnp.float32),
        probs=f((c,), jnp.float32), seeds=f((nf, c), jnp.float32),
        member=f((c,), bool), aux=f((c,), bool), valid=f((c,), bool),
        taus=f((nf,), jnp.float32))
