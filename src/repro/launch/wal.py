"""Per-stream write-ahead log for the serving tier (launch.pool).

Durability layer under ``EnginePool``: every accepted absorb chunk is
appended here — fsync'd, crc-framed — BEFORE the device fold runs, so a
crash (or a fold failure behind an open circuit breaker) never loses
ingested data. Recovery is restore-checkpoint -> replay the WAL tail
(records with seq past the checkpoint's applied sequence) -> lazy merge;
because the fold is deterministic and checkpoints store exact slab bits,
the recovered engine is BIT-IDENTICAL to the uncrashed one (the
serving-tier failure-semantics contract, core.merge docstring).

Record framing (little-endian):

  magic  4s   b"MOW1"
  seq    u64  strictly increasing per stream (gaps allowed after pruning)
  shard  i32  target engine shard
  n      i32  row count
  crc    u32  crc32 over (seq, shard, n, payload)
  payload     keys int32[n] + weights float32[n] + active uint8[n]

Replay stops at the first torn/corrupt frame (short read, bad magic, crc
mismatch, non-increasing seq): a torn tail — the expected crash artifact —
silently yields every complete record before it; mid-file corruption is
treated the same way (conservative: the seq chain past it is suspect).

GC markers: a record with ``shard == GC_SHARD`` (-1) is a shard-GC
directive, not data — its ``keys`` payload holds the VICTIM shard
indices (int32) the engine merged into its base slab; weights/active are
padding. The pool appends the marker AFTER a successful ``gc_apply``
(apply-then-append: a crash between the two loses only the GC directive,
never data, and the merged union — hence every query answer — is
identical either way), and recovery replays it as
``engine.gc_apply(keys)`` so the restored shard layout matches the
uncrashed engine's exactly. Replay of data records must therefore
dispatch on the shard sign.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Iterator, NamedTuple, Optional

import numpy as np

_MAGIC = b"MOW1"
GC_SHARD = -1                  # marker record: keys = GC victim indices
_HEADER = struct.Struct("<4sQiiI")
_BODY = struct.Struct("<QiI")  # the crc-covered header fields (seq, shard, n)
_MAX_ROWS = 1 << 24            # frame sanity bound (rejects garbage lengths)


class WalRecord(NamedTuple):
    seq: int
    shard: int
    keys: np.ndarray     # int32 [n]
    weights: np.ndarray  # float32 [n]
    active: np.ndarray   # bool [n]


def _frame(seq: int, shard: int, keys, weights, active) -> bytes:
    keys = np.ascontiguousarray(keys, np.int32)
    weights = np.ascontiguousarray(weights, np.float32)
    active = np.ascontiguousarray(active, np.uint8)
    n = keys.shape[0]
    payload = keys.tobytes() + weights.tobytes() + active.tobytes()
    crc = zlib.crc32(_BODY.pack(seq, shard, n) + payload) & 0xFFFFFFFF
    return _HEADER.pack(_MAGIC, seq, shard, n, crc) + payload


class WriteAheadLog:
    """Append-only fsync'd chunk log; one file per stream."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "ab")

    # ------------------------------------------------------------- write
    def append(self, seq: int, shard: int, keys, weights, active):
        """Durably append one chunk record (fsync before returning — the
        write-ahead guarantee: once ``absorb`` acks, the chunk survives a
        crash even if its device fold never ran)."""
        self._f.write(_frame(int(seq), int(shard), keys, weights, active))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def prune(self, min_seq_exclusive: int):
        """Atomically rewrite the log keeping records with
        seq > ``min_seq_exclusive`` — called after a checkpoint snapshot so
        the log stays O(data since the oldest RETAINED snapshot), never
        O(stream lifetime)."""
        keep = [r for r in self.replay() if r.seq > min_seq_exclusive]
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for r in keep:
                f.write(_frame(r.seq, r.shard, r.keys, r.weights, r.active))
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        d = os.path.dirname(self.path) or "."
        fd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        self._f = open(self.path, "ab")

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    # -------------------------------------------------------------- read
    def replay(self, min_seq_exclusive: int = 0) -> Iterator[WalRecord]:
        """Yield intact records in order, stopping at the first torn or
        corrupt frame. Safe on a live log (reads a separate handle)."""
        self._f.flush()
        last_seq = 0
        with open(self.path, "rb") as f:
            while True:
                head = f.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    return                       # EOF or torn header
                magic, seq, shard, n, crc = _HEADER.unpack(head)
                if magic != _MAGIC or not (0 <= n <= _MAX_ROWS):
                    return                       # corrupt frame
                payload = f.read(9 * n)
                if len(payload) < 9 * n:
                    return                       # torn payload
                if zlib.crc32(_BODY.pack(seq, shard, n) + payload) \
                        & 0xFFFFFFFF != crc:
                    return                       # bit rot / torn write
                if seq <= last_seq:
                    return                       # seq chain broken
                last_seq = seq
                if seq <= min_seq_exclusive:
                    continue
                keys = np.frombuffer(payload, np.int32, n, 0).copy()
                weights = np.frombuffer(payload, np.float32, n, 4 * n).copy()
                active = np.frombuffer(payload, np.uint8, n, 8 * n
                                       ).astype(bool)
                yield WalRecord(seq, shard, keys, weights, active)

    def last_seq(self) -> int:
        """Highest intact sequence number (0 when empty)."""
        seq = 0
        for r in self.replay():
            seq = r.seq
        return seq
