"""Per-stream write-ahead log for the serving tier (launch.pool).

Durability layer under ``EnginePool``: every accepted absorb chunk is
appended here — fsync'd, crc-framed — BEFORE the device fold runs, so a
crash (or a fold failure behind an open circuit breaker) never loses
ingested data. Recovery is restore-checkpoint -> replay the WAL tail
(records with seq past the checkpoint's applied sequence) -> lazy merge;
because the fold is deterministic and checkpoints store exact slab bits,
the recovered engine is BIT-IDENTICAL to the uncrashed one (the
serving-tier failure-semantics contract, core.merge docstring).

Record framing (little-endian):

  magic  4s   b"MOW1"
  seq    u64  strictly increasing per stream (gaps allowed after pruning)
  shard  i32  target engine shard
  n      i32  row count
  crc    u32  crc32 over (seq, shard, n, payload)
  payload     keys int32[n] + weights float32[n] + active uint8[n]

Replay stops at the first torn/corrupt frame (short read, bad magic, crc
mismatch, non-increasing seq): a torn tail — the expected crash artifact —
silently yields every complete record before it; mid-file corruption is
treated the same way (conservative: the seq chain past it is suspect).

Control markers: a record with a NEGATIVE ``shard`` is a directive, not
data — replay must dispatch on the shard tag. Two kinds:

  * GC markers (``shard == GC_SHARD``, -1): the ``keys`` payload holds
    the VICTIM shard indices (int32) the engine merged into its base
    slab; weights/active are padding. The pool appends the marker AFTER
    a successful ``gc_apply`` (apply-then-append: a crash between the
    two loses only the GC directive, never data, and the merged union —
    hence every query answer — is identical either way), and recovery
    replays it as ``engine.gc_apply(keys)`` so the restored shard layout
    matches the uncrashed engine's exactly.
  * REBALANCE markers (``shard == REBALANCE_SHARD``, -2): the ``keys``
    payload holds the COMPLETE shard->host placement (keys[i] = owner
    host id of global shard i) a ``ShardedEnginePool`` re-partition
    moved to. Same apply-then-append discipline: recovery replays data +
    GC + rebalance markers in seq order and lands in the identical
    post-move layout, while a marker lost to a crash merely recovers the
    PRE-move placement — whose merged union, hence every answer, is
    bit-identical (launch.pool docstring, core.merge contract).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Iterator, NamedTuple, Optional

import numpy as np

_MAGIC = b"MOW1"
GC_SHARD = -1                  # marker record: keys = GC victim indices
REBALANCE_SHARD = -2           # marker record: keys = shard->host placement
_HEADER = struct.Struct("<4sQiiI")
_BODY = struct.Struct("<QiI")  # the crc-covered header fields (seq, shard, n)
_MAX_ROWS = 1 << 24            # frame sanity bound (rejects garbage lengths)


class WalRecord(NamedTuple):
    seq: int
    shard: int
    keys: np.ndarray     # int32 [n]
    weights: np.ndarray  # float32 [n]
    active: np.ndarray   # bool [n]


def _frame(seq: int, shard: int, keys, weights, active) -> bytes:
    keys = np.ascontiguousarray(keys, np.int32)
    weights = np.ascontiguousarray(weights, np.float32)
    active = np.ascontiguousarray(active, np.uint8)
    n = keys.shape[0]
    payload = keys.tobytes() + weights.tobytes() + active.tobytes()
    crc = zlib.crc32(_BODY.pack(seq, shard, n) + payload) & 0xFFFFFFFF
    return _HEADER.pack(_MAGIC, seq, shard, n, crc) + payload


class WriteAheadLog:
    """Append-only fsync'd chunk log; one file per stream."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        existed = os.path.exists(path)
        self._f = open(path, "ab")
        # highest intact seq, maintained incrementally: a brand-new/empty
        # log is known-0; an adopted non-empty log is unknown until the
        # first ``last_seq`` scan. ``append``/``prune`` keep it current so
        # steady-state ``last_seq`` never re-reads the file.
        self._last_seq: Optional[int] = 0 if self._f.tell() == 0 else None
        if not existed:
            # the file's first durability point: fsync the PARENT DIRECTORY
            # too, or a crash right after the first fsync'd ``append`` can
            # lose the directory entry — frame durable, file unreachable
            # (``prune`` already does this after its os.replace)
            if self.fsync:
                self._fsync_dir()

    def _fsync_dir(self):
        d = os.path.dirname(self.path) or "."
        fd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    # ------------------------------------------------------------- write
    def append(self, seq: int, shard: int, keys, weights, active):
        """Durably append one chunk record (fsync before returning — the
        write-ahead guarantee: once ``absorb`` acks, the chunk survives a
        crash even if its device fold never ran)."""
        if self._f is None:
            raise ValueError(
                f"append(seq={seq}) on closed WAL {self.path!r} — the log "
                f"was close()d; reopen with WriteAheadLog(path)")
        seq = int(seq)
        self._f.write(_frame(seq, int(shard), keys, weights, active))
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        if self._last_seq is not None:
            if seq > self._last_seq:
                self._last_seq = seq
            else:
                # non-increasing append breaks the replay seq chain at an
                # earlier frame — the cached value no longer tracks it
                self._last_seq = None

    def prune(self, min_seq_exclusive: int):
        """Atomically rewrite the log keeping records with
        seq > ``min_seq_exclusive`` — called after a checkpoint snapshot so
        the log stays O(data since the oldest RETAINED snapshot), never
        O(stream lifetime).

        Streaming frame copy: each frame is validated (magic/length/crc/
        seq chain — the ``replay`` acceptance rules) and its RAW BYTES
        written through, one frame in memory at a time — pruning a
        near-full log is O(frame) memory, never O(log), and the retained
        bytes are identical to the source frames."""
        if self._f is None:
            raise ValueError(f"prune() on closed WAL {self.path!r}")
        self._f.flush()
        tmp = self.path + ".tmp"
        last_seq = 0
        last_kept = 0
        with open(self.path, "rb") as src, open(tmp, "wb") as dst:
            while True:
                head = src.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    break                    # EOF or torn header
                magic, seq, shard, n, crc = _HEADER.unpack(head)
                if magic != _MAGIC or not (0 <= n <= _MAX_ROWS):
                    break                    # corrupt frame
                payload = src.read(9 * n)
                if len(payload) < 9 * n:
                    break                    # torn payload
                if zlib.crc32(_BODY.pack(seq, shard, n) + payload) \
                        & 0xFFFFFFFF != crc:
                    break                    # bit rot / torn write
                if seq <= last_seq:
                    break                    # seq chain broken
                last_seq = seq
                if seq > min_seq_exclusive:
                    dst.write(head)
                    dst.write(payload)
                    last_kept = seq
            dst.flush()
            os.fsync(dst.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._fsync_dir()
        self._f = open(self.path, "ab")
        self._last_seq = last_kept

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    # -------------------------------------------------------------- read
    def replay(self, min_seq_exclusive: int = 0) -> Iterator[WalRecord]:
        """Yield intact records in order, stopping at the first torn or
        corrupt frame. Safe on a live log (reads a separate handle)."""
        if self._f is not None:
            self._f.flush()
        last_seq = 0
        with open(self.path, "rb") as f:
            while True:
                head = f.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    return                       # EOF or torn header
                magic, seq, shard, n, crc = _HEADER.unpack(head)
                if magic != _MAGIC or not (0 <= n <= _MAX_ROWS):
                    return                       # corrupt frame
                payload = f.read(9 * n)
                if len(payload) < 9 * n:
                    return                       # torn payload
                if zlib.crc32(_BODY.pack(seq, shard, n) + payload) \
                        & 0xFFFFFFFF != crc:
                    return                       # bit rot / torn write
                if seq <= last_seq:
                    return                       # seq chain broken
                last_seq = seq
                if seq <= min_seq_exclusive:
                    continue
                keys = np.frombuffer(payload, np.int32, n, 0).copy()
                weights = np.frombuffer(payload, np.float32, n, 4 * n).copy()
                active = np.frombuffer(payload, np.uint8, n, 8 * n
                                       ).astype(bool)
                yield WalRecord(seq, shard, keys, weights, active)

    def last_seq(self) -> int:
        """Highest intact sequence number (0 when empty). Cached: computed
        by one replay scan at most once per adopted log, then maintained
        incrementally by ``append``/``prune`` — steady-state calls never
        re-read the file."""
        if self._last_seq is None:
            seq = 0
            for r in self.replay():
                seq = r.seq
            self._last_seq = seq
        return self._last_seq
