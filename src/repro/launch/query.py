"""Device-resident segment-query engine (the serving tier).

The sharded build (launch.summary) re-selects the merged sample EAGERLY,
replicated on every device, on every build — wasted work when the summary
is rebuilt often and queried rarely, and the wrong shape for serving where
per-shard sketches trickle in (telemetry collectors, checkpointed slabs,
cross-job merges). This engine is the lazy counterpart, the "precompute a
compact sampled structure once, answer many queries cheaply" pattern of
distance-oracle sampling (arXiv:1203.4903):

  * per-shard ``MultiSketch`` slabs stay RESIDENT on device — absorbing a
    chunk touches only its shard's slab (the jit'd donated streaming fold);
  * the merged slab is materialized ON DEMAND (one stacked re-selection,
    jit-cached per spec) and memoized until the next absorb/update bumps
    the epoch — repeated queries between updates pay ZERO merge work, and
    exactness is the threshold-closure merge invariant (core.merge
    docstring);
  * ``query_many`` answers a batch of B segment predicates x |F|
    objectives in ONE fused launch over the merged slab
    (kernels.segquery), with B bucketed to a quantum so jit traces stay
    bounded. Single ``query`` calls route through the same batched path —
    a repeated query is O(1) launches, never a retrace.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.funcs import StatFn
from repro.core.multi_sketch import (MultiSketch, MultiSketchSpec,
                                     multisketch_absorb,
                                     multisketch_absorb_slabs,
                                     multisketch_empty,
                                     multisketch_merge_stacked,
                                     multisketch_overflow,
                                     multisketch_query_many, pad_chunk)
from repro.core.predicates import EVERYTHING, SegmentPredicate


@partial(jax.jit, static_argnames=("spec", "use_kernels"))
def _merge_stacked_jit(stacked, *, spec, use_kernels):
    """jit-cached merge-on-demand: one re-selection (batched top_k reuse)
    per epoch, shared across every query until the next absorb."""
    return multisketch_merge_stacked(spec, stacked, use_kernels)


class SegmentQueryEngine:
    """Resident per-shard MultiSketches + lazy merge + batched queries.

    One engine serves every (f, H) query the spec's objectives cover; the
    per-objective CV guarantee (paper Thm 3.1) is the serving SLO.
    """

    def __init__(self, spec: MultiSketchSpec, shards: int = 1,
                 b_quantum: int = 16, chunk: int = 256,
                 use_kernels: Optional[bool] = None,
                 max_delta: Optional[int] = None):
        if shards < 1:
            raise ValueError(f"need >= 1 shard, got {shards}")
        self.spec = spec
        self.b_quantum = int(b_quantum)
        self.chunk = int(chunk)
        self.use_kernels = use_kernels
        # incremental-merge eligibility ceiling: fold at most this many
        # dirty shards into the cached merged slab before a full re-merge
        # is the cheaper rebuild (None -> any strict subset of the shards)
        self.max_delta = max_delta
        self._shards = [multisketch_empty(spec) for _ in range(shards)]
        self._epoch = 0            # bumped by every state mutation
        self._merged: Optional[MultiSketch] = None
        self._merged_epoch = -1    # epoch the cached merged slab reflects
        # -- dirty-epoch tracking (the incremental-merge contract) --------
        # _shard_epochs[i]: epoch of shard i's last mutation; _merged_base:
        # snapshot of _shard_epochs the cached merged slab reflects (None
        # after a non-monotone mutation — set_shard/load_stacked replace
        # data, so the cached merge no longer covers the residents and the
        # delta fold would be inexact; only a full re-merge recovers).
        self._shard_epochs = [0] * shards
        self._merged_base: Optional[list] = None
        self._merged_handed_out = False   # `merged` property gave out refs
        # full / incremental / hit counts — the launch-accounting record
        # (tests pin "incremental epoch => delta fold only, no full merge")
        # — plus the saturation health flag: ``overflow`` goes True when a
        # materialized merged slab is FULL, i.e. compaction may have
        # truncated S ∪ Z and the cv guarantee silently degrades; serving
        # tiers surface it in every response (launch.pool)
        self.merge_stats = {"full": 0, "incremental": 0, "hit": 0,
                            "overflow": False}

    # -- resident state ----------------------------------------------------
    @property
    def num_shards(self) -> int:
        return len(self._shards)

    @property
    def epoch(self) -> int:
        return self._epoch

    def absorb(self, keys, weights, active=None, shard: int = 0):
        """Fold a chunk into one shard's resident slab (donated device fold);
        invalidates the merged-slab cache."""
        # a handed-out ``merged`` slab may ALIAS this shard's live state
        # (the single-shard fast path); re-point the shard at fresh buffers
        # first, so the donated fold cannot invalidate the caller's copy
        if self._merged is not None and self._merged is self._shards[shard]:
            self._shards[shard] = jax.tree.map(jnp.copy,
                                               self._shards[shard])
        keys, weights, active = pad_chunk(keys, weights, active, self.chunk)
        self._shards[shard] = multisketch_absorb(
            self._shards[shard], keys, weights, active, spec=self.spec,
            use_kernels=self.use_kernels)
        self._epoch += 1
        self._shard_epochs[shard] = self._epoch

    def set_shard(self, shard: int, sketch: MultiSketch):
        """Install a prebuilt slab (a collector's state, a checkpointed
        sketch, a slab wired from another job) as one shard's residency.
        The slab is COPIED in: a later absorb on this shard donates the
        resident buffers, and the caller's handle must stay valid.

        Replacing a shard's content is NON-MONOTONE (the old contribution
        may vanish), so the cached merged slab is dropped entirely — the
        next query takes the full re-merge path, never the delta fold."""
        self._shards[shard] = jax.tree.map(jnp.copy, sketch)
        self._epoch += 1
        self._shard_epochs[shard] = self._epoch
        self._drop_merged_cache()

    def add_shard(self, sketch: MultiSketch):
        """Append a prebuilt slab as a NEW shard (copied in, like
        ``set_shard``) — cross-job fan-in: slabs restored from another
        job's checkpoint merge lazily with the resident state. A new shard
        only ADDS data, so it rides the incremental path: the next query
        folds just the new slab into the cached merge."""
        self._shards.append(jax.tree.map(jnp.copy, sketch))
        self._epoch += 1
        self._shard_epochs.append(self._epoch)

    def load_stacked(self, stacked: MultiSketch):
        """Adopt a stacked batch of per-shard slabs (leaves [m, ...], e.g.
        from ``launch.summary.sharded_multisketch_shards``) as the resident
        state — the merge stays lazy until the first query. Wholesale
        replacement: the merged-slab cache is dropped (full path next)."""
        m = stacked.keys.shape[0]
        self._shards = [jax.tree.map(lambda x, i=i: x[i], stacked)
                        for i in range(m)]
        self._epoch += 1
        self._shard_epochs = [self._epoch] * m
        self._drop_merged_cache()

    def _drop_merged_cache(self):
        self._merged = None
        self._merged_epoch = -1
        self._merged_base = None
        self._merged_handed_out = False

    @classmethod
    def from_sharded(cls, spec: MultiSketchSpec, mesh, keys, weights,
                     active=None, axis: str = "data", **kw
                     ) -> "SegmentQueryEngine":
        """Build per-shard slabs over mesh-sharded data (local selection
        only — no replicated merge) and hold them resident."""
        from repro.launch.summary import sharded_multisketch_shards
        stacked = sharded_multisketch_shards(spec, mesh, keys, weights,
                                             active, axis=axis)
        eng = cls(spec, shards=stacked.keys.shape[0], **kw)
        eng.load_stacked(stacked)
        return eng

    # -- checkpointing -----------------------------------------------------
    def save_checkpoint(self, directory: str, step: Optional[int] = None,
                        blocking: bool = True,
                        extra_meta: Optional[dict] = None):
        """Persist the resident per-shard slabs + the spec (as JSON extra
        metadata) through ckpt.manager — atomic, crc-checked, keep-last-k.
        The slabs are plain arrays, so the checkpoint is mesh- and
        job-agnostic: any process restores it with ``from_checkpoint`` and
        merges it with its own state (threshold closure keeps that exact).

        ``step`` defaults to one past the newest existing step — the
        manager treats an already-present step as saved and skips it, so
        re-saving an updated engine must mint a fresh step number.
        ``extra_meta``: caller-owned JSON-able entries merged into the
        stored metadata (e.g. the serving pool's applied WAL sequence) —
        engine keys win on collision.
        """
        from repro.ckpt.manager import CheckpointManager
        from repro.core.multi_sketch import spec_to_meta
        mgr = CheckpointManager(directory)
        if step is None:
            step = max(mgr.list_steps(), default=-1) + 1
        ex = dict(extra_meta or {})
        ex.update({"multisketch_spec": spec_to_meta(self.spec),
                   "num_shards": len(self._shards),
                   "b_quantum": self.b_quantum,
                   "chunk": self.chunk,
                   "max_delta": self.max_delta})
        mgr.save(step, {"shards": list(self._shards)}, blocking=blocking,
                 extra_meta=ex)
        return mgr

    @classmethod
    def from_checkpoint(cls, directory: str,
                        use_kernels: Optional[bool] = None,
                        return_meta: bool = False):
        """Rebuild an engine from the newest intact checkpoint: the spec
        comes from the stored metadata, the per-shard slabs from the
        crc-verified arrays — BOTH from the SAME step, falling back step by
        step when one is corrupt (a newer save's spec must never be paired
        with an older save's slabs). Queries over the restored engine are
        bit-identical to the saved one's (the slabs ARE the state).

        ``return_meta=True`` -> ``(engine, extra)`` where ``extra`` is the
        restored step's OWN extra-metadata dict — callers recovering
        stateful context (e.g. the pool's applied WAL sequence) need it
        from the step actually restored, not the newest one written."""
        from repro.ckpt.manager import CheckpointManager
        from repro.core.multi_sketch import spec_from_meta
        mgr = CheckpointManager(directory)
        for step in reversed(mgr.list_steps()):
            try:
                _, meta = mgr.read_meta(step)
                ex = meta["extra"]
                spec = spec_from_meta(ex["multisketch_spec"])
                num_shards = int(ex["num_shards"])
            except (FileNotFoundError, KeyError, ValueError, TypeError):
                continue
            template = {"shards": [multisketch_empty(spec)
                                   for _ in range(num_shards)]}
            state = mgr.restore_step(step, template)
            if state is None:
                continue
            md = ex.get("max_delta")
            eng = cls(spec, shards=num_shards,
                      b_quantum=int(ex.get("b_quantum", 16)),
                      chunk=int(ex.get("chunk", 256)),
                      use_kernels=use_kernels,
                      max_delta=None if md is None else int(md))
            eng._shards = [MultiSketch(*(jnp.asarray(x) for x in s))
                           for s in state["shards"]]
            eng._epoch += 1
            eng._shard_epochs = [eng._epoch] * num_shards
            return (eng, ex) if return_meta else eng
        raise FileNotFoundError(
            f"no intact checkpoint restorable under {directory}")

    # -- lazy merge-on-demand ----------------------------------------------
    def _dirty_shards(self) -> Optional[list]:
        """Shard indices mutated since the cached merge, or None when the
        cache can't seed an incremental fold (no cache / non-monotone
        history / truncating capacity, where delta != full bit-for-bit)."""
        if (self._merged is None or self._merged_base is None
                or self.spec.cap < self.spec.default_capacity()):
            return None
        base = self._merged_base
        return [i for i in range(len(self._shards))
                if i >= len(base) or self._shard_epochs[i] > base[i]]

    def _incremental_eligible(self, dirty: Optional[list]) -> bool:
        if dirty is None or not dirty:
            return False
        limit = (len(self._shards) - 1 if self.max_delta is None
                 else self.max_delta)
        return len(dirty) <= max(limit, 0)

    def _materialize_merged(self) -> MultiSketch:
        """The merged slab, maintained at most once per epoch: a cache hit,
        an INCREMENTAL delta fold (absorb the dirty shards' slabs into the
        cached merged slab — donated buffers, exact by threshold closure,
        bit-identical to the full path), or the full stacked re-merge."""
        if self._merged_epoch == self._epoch:
            self.merge_stats["hit"] += 1
            return self._merged
        dirty = self._dirty_shards()
        if self._incremental_eligible(dirty):
            merged = self._merged
            if self._merged_handed_out or any(
                    merged is s for s in self._shards):
                # the cached slab is visible outside the engine (a caller
                # handle, or the single-shard alias of a live shard) — the
                # delta fold donates its buffers, so re-point at fresh ones
                merged = jax.tree.map(jnp.copy, merged)
                self._merged_handed_out = False
            if len(dirty) == 1:
                d = self._shards[dirty[0]]
                dk, dw, dv = d.keys, d.weights, d.valid
            else:
                # stack only the three leaves the delta fold consumes —
                # probs/seeds/member/aux/taus are recomputed by the
                # re-selection and would be copied just to be discarded
                dk = jnp.stack([self._shards[i].keys for i in dirty])
                dw = jnp.stack([self._shards[i].weights for i in dirty])
                dv = jnp.stack([self._shards[i].valid for i in dirty])
            self._merged = multisketch_absorb_slabs(
                merged, dk, dw, dv, spec=self.spec,
                use_kernels=self.use_kernels)
            self.merge_stats["incremental"] += 1
        elif len(self._shards) == 1:
            self._merged = self._shards[0]
            self.merge_stats["full"] += 1
        else:
            stacked = MultiSketch(*jax.tree.map(
                lambda *xs: jnp.stack(xs), *self._shards))
            self._merged = _merge_stacked_jit(
                stacked, spec=self.spec,
                use_kernels=(True if self.use_kernels is None
                             else self.use_kernels))
            self.merge_stats["full"] += 1
        self._merged_epoch = self._epoch
        self._merged_base = list(self._shard_epochs)
        self.merge_stats["overflow"] = bool(multisketch_overflow(self._merged))
        return self._merged

    @property
    def merged(self) -> MultiSketch:
        """The merged slab, materialized at most once per epoch. The handle
        stays valid across later updates: the next incremental fold donates
        only engine-owned buffers (a handed-out slab is re-pointed first,
        same discipline as ``absorb`` on the single-shard alias)."""
        sk = self._materialize_merged()
        self._merged_handed_out = True
        return sk

    # -- queries -----------------------------------------------------------
    def query_many(self, fs: Optional[Sequence[StatFn]] = None,
                   predicates=EVERYTHING) -> np.ndarray:
        """Q(f_i, H_b) for every objective x predicate -> float [|F|, B].

        ONE fused launch over the merged slab regardless of B and |F|
        (kernels.segquery); B is padded to ``b_quantum`` with never-matching
        predicates so same-bucket batches share one compiled executable.
        """
        fs = (tuple(f for f, _ in self.spec.objectives) if fs is None
              else tuple(fs))
        # internal access: queries read the slab without marking it handed
        # out, so the next delta fold may still donate its buffers
        return multisketch_query_many(self._materialize_merged(), fs,
                                      predicates, b_quantum=self.b_quantum,
                                      use_kernels=self.use_kernels)

    def query(self, f: StatFn, predicate: SegmentPredicate = EVERYTHING
              ) -> float:
        """Single Q(f, H) — routed through the batched path (same compiled
        executable as any 1-query batch of this objective)."""
        return float(self.query_many((f,), predicate)[0, 0])
