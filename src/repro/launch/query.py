"""Device-resident segment-query engine (the serving tier).

The sharded build (launch.summary) re-selects the merged sample EAGERLY,
replicated on every device, on every build — wasted work when the summary
is rebuilt often and queried rarely, and the wrong shape for serving where
per-shard sketches trickle in (telemetry collectors, checkpointed slabs,
cross-job merges). This engine is the lazy counterpart, the "precompute a
compact sampled structure once, answer many queries cheaply" pattern of
distance-oracle sampling (arXiv:1203.4903):

  * per-shard ``MultiSketch`` slabs stay RESIDENT on device — absorbing a
    chunk touches only its shard's slab (the jit'd donated streaming fold);
  * the merged slab is maintained AT ABSORB TIME (the default): after the
    shard fold, the post-fold shard slab is delta-folded into the cached
    merged slab in the same donated epoch — the exact arithmetic of the
    lazy ladder's incremental path, run one query early — so queries
    under churn hit an always-fresh cache and pay ZERO merge work;
    exactness is the threshold-closure merge invariant (core.merge
    docstring). When the cache is cold or stale (first query, restore,
    non-monotone mutation) materialization falls back to the PR 5 lazy
    ladder: cache hit -> incremental delta fold of the dirty shards ->
    full stacked re-merge;
  * shard LIFECYCLE bounds a long-running engine's memory: ``gc`` merges
    cold shards (absorb-epoch age / live-count water-marks) into the
    compacted base slab (shard 0), parks the victims on one shared inert
    slab, and truncates trailing dead shards — device residency stays
    O(capacity), not O(epochs). ``spill`` persists victims through the
    checkpoint manager first (evict-to-disk hook);
  * ``query_many`` answers a batch of B segment predicates x |F|
    objectives in ONE fused launch over the merged slab
    (kernels.segquery), with B bucketed to a quantum so jit traces stay
    bounded. Single ``query`` calls route through the same batched path —
    a repeated query is O(1) launches, never a retrace.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.funcs import StatFn
from repro.core.multi_sketch import (MultiSketch, MultiSketchSpec,
                                     multisketch_absorb,
                                     multisketch_absorb_slabs,
                                     multisketch_empty,
                                     multisketch_overflow,
                                     multisketch_query_many, pad_chunk)
from repro.core.predicates import EVERYTHING, SegmentPredicate


def _full_remerge(shards, *, spec, use_kernels):
    """Full re-merge expressed as a stacked delta fold into a fresh empty
    slab — the SAME compiled program family (``_absorb_into_jit``) as the
    incremental and absorb-time folds. Routing every merged-slab producer
    through one program keeps the merged bits identical regardless of
    path: XLA codegens transcendentals (the ppswor ``-expm1(-w*tau)``
    inclusion probability) with shape-dependent last-ulp rounding, so a
    separately jitted ``multisketch_merge_stacked`` at [m, cap] can
    disagree with the [cap]-delta fold by one ulp in ``probs`` even
    though the retained multiset is exact by threshold closure."""
    dk = jnp.stack([s.keys for s in shards])
    dw = jnp.stack([s.weights for s in shards])
    dv = jnp.stack([s.valid for s in shards])
    empty = jax.tree.map(jnp.copy, multisketch_empty(spec))
    return multisketch_absorb_slabs(empty, dk, dw, dv, spec=spec,
                                    use_kernels=use_kernels)


class SegmentQueryEngine:
    """Resident per-shard MultiSketches + lazy merge + batched queries.

    One engine serves every (f, H) query the spec's objectives cover; the
    per-objective CV guarantee (paper Thm 3.1) is the serving SLO.
    """

    def __init__(self, spec: MultiSketchSpec, shards: int = 1,
                 b_quantum: int = 16, chunk: int = 256,
                 use_kernels: Optional[bool] = None,
                 max_delta: Optional[int] = None,
                 absorb_time: bool = True,
                 gc_max_live: Optional[int] = None):
        if shards < 1:
            raise ValueError(f"need >= 1 shard, got {shards}")
        self.spec = spec
        self.b_quantum = int(b_quantum)
        self.chunk = int(chunk)
        self.use_kernels = use_kernels
        # incremental-merge eligibility ceiling: fold at most this many
        # dirty shards into the cached merged slab before a full re-merge
        # is the cheaper rebuild (None -> any strict subset of the shards)
        self.max_delta = max_delta
        # absorb-time merged-slab maintenance: fold each chunk into the
        # cached merged slab in the SAME epoch as its shard fold, so the
        # query path never pays merge work under churn. False reverts to
        # the PR 5 query-time lazy ladder (hit / delta fold / full merge).
        self.absorb_time = bool(absorb_time)
        # auto-GC water-mark: after any mutation that grows the live shard
        # count past this bound, cold shards are merged into the base slab
        # (None -> manual ``gc`` only). Deterministic in the absorb history,
        # so a WAL replay reproduces every auto-GC at the same point.
        self.gc_max_live = (None if gc_max_live is None
                            else max(int(gc_max_live), 1))
        # one shared inert slab backs never-touched and GC'd shards — the
        # donated fold re-points a shard at fresh buffers before its first
        # absorb, so device residency is O(live shards), not O(shards)
        self._empty = multisketch_empty(spec)
        self._shards = [self._empty for _ in range(shards)]
        self._min_shards = shards  # construction layout: never truncated
        self._epoch = 0            # bumped by every state mutation
        self.last_gc_epoch = -1    # epoch of the most recent GC merge
        self._merged: Optional[MultiSketch] = None
        self._merged_epoch = -1    # epoch the cached merged slab reflects
        self._overflow_epoch = -1  # epoch merge_stats["overflow"] reflects
        self._overflow_dev = None  # (epoch, device scalar) pre-dispatched
        # -- dirty-epoch tracking (the incremental-merge contract) --------
        # _shard_epochs[i]: epoch of shard i's last mutation; _merged_base:
        # snapshot of _shard_epochs the cached merged slab reflects (None
        # after a non-monotone mutation — set_shard/load_stacked replace
        # data, so the cached merge no longer covers the residents and the
        # delta fold would be inexact; only a full re-merge recovers).
        self._shard_epochs = [0] * shards
        self._shard_live = [False] * shards  # holds data (host-side gauge)
        self._merged_base: Optional[list] = None
        self._merged_handed_out = False   # `merged` property gave out refs
        # full / incremental / hit / absorb_time / gc_merges counts — the
        # launch-accounting record (tests pin "zero-merge epoch => no
        # query-time fold dispatch") — plus gauges (live_shards,
        # bytes_resident) and the saturation health flag: ``overflow``
        # goes True when a materialized merged slab is FULL, i.e.
        # compaction may have truncated S ∪ Z and the cv guarantee
        # silently degrades; serving tiers surface it per response
        # (launch.pool)
        self.merge_stats = {"full": 0, "incremental": 0, "hit": 0,
                            "absorb_time": 0, "gc_merges": 0,
                            "live_shards": 0, "bytes_resident": 0,
                            "overflow": False}
        self._update_gauges()

    # -- resident state ----------------------------------------------------
    @property
    def num_shards(self) -> int:
        return len(self._shards)

    @property
    def epoch(self) -> int:
        return self._epoch

    def absorb(self, keys, weights, active=None, shard: int = 0):
        """Fold a chunk into one shard's resident slab (donated device
        fold). With ``absorb_time`` (the default) the POST-FOLD shard slab
        is then delta-folded into the cached merged slab in the same epoch
        — the exact computation (same executable, same input slabs, hence
        the same bits) the lazy ladder would run at the next query — so
        the next query is a pure cache hit: zero merge work on the query
        path, bit-identical to the lazy full re-merge by threshold
        closure. NOT the raw chunk: the maintained fold must reproduce the
        query-time delta fold's arithmetic exactly, and folding the
        un-selected chunk runs the re-selection over a different input
        shape (last-ulp transcendental drift in probs). A cold/stale cache
        skips maintenance (the lazy ladder at query time remains the
        fallback and re-seeds it)."""
        if not 0 <= shard < len(self._shards):
            raise IndexError(f"shard {shard} out of range "
                             f"({len(self._shards)} shards)")
        # absorb-time eligibility, judged BEFORE the epoch bump: the cache
        # must be CURRENT (every prior epoch already folded in), seeded
        # from a monotone history, at a non-truncating capacity (where
        # delta == full bit-for-bit — same gate as ``_dirty_shards``)
        maintain = (self.absorb_time and self._merged is not None
                    and self._merged_epoch == self._epoch
                    and self._merged_base is not None
                    and self.spec.cap >= self.spec.default_capacity())
        alias = (self._merged is not None
                 and self._merged is self._shards[shard])
        # single-shard fast path: when the maintained cache ALIASES the
        # target shard, the shard fold IS the merged-slab fold — re-alias
        # after it instead of folding the chunk twice
        realias = maintain and alias
        if self._shards[shard] is self._empty:
            # never-touched / GC'd shards share one inert slab; give the
            # donated fold its own buffers
            self._shards[shard] = jax.tree.map(jnp.copy, self._empty)
        elif alias and (self._merged_handed_out or not realias):
            # a handed-out ``merged`` slab may ALIAS this shard's live
            # state (the single-shard fast path); re-point the shard at
            # fresh buffers first, so the donated fold cannot invalidate
            # the caller's copy
            self._shards[shard] = jax.tree.map(jnp.copy,
                                               self._shards[shard])
        keys, weights, active = pad_chunk(keys, weights, active, self.chunk)
        self._shards[shard] = multisketch_absorb(
            self._shards[shard], keys, weights, active, spec=self.spec,
            use_kernels=self.use_kernels)
        self._epoch += 1
        self._shard_epochs[shard] = self._epoch
        self._shard_live[shard] = True
        if realias:
            self._merged = self._shards[shard]
            self._merged_handed_out = False
            self._stamp_absorb_time()
        elif maintain:
            merged = self._merged
            if (self._merged_handed_out or merged is self._empty
                    or any(merged is s for s in self._shards)):
                # visible outside the engine (caller handle / shared inert
                # slab / shard alias) — the donated fold needs its own
                # buffers
                merged = jax.tree.map(jnp.copy, merged)
                self._merged_handed_out = False
            # the shard's whole slab is the delta (dedup-by-max-weight
            # makes re-folding its older rows a no-op) — the single-dirty-
            # shard delta fold of the lazy ladder, run one query early
            d = self._shards[shard]
            self._merged = multisketch_absorb_slabs(
                merged, d.keys, d.weights, d.valid, spec=self.spec,
                use_kernels=self.use_kernels)
            self._stamp_absorb_time()
        self._maybe_auto_gc()
        self._update_gauges()

    def drain(self) -> None:
        """Block until every async-dispatched device computation behind
        the current state has executed — shard folds, absorb-time merged-
        slab maintenance (including the probs finalize) and the pre-
        dispatched saturation flag. Absorb never blocks; a serving pump
        calls this between requests so no query pays for the previous
        epoch's device backlog on its critical path."""
        pending = [self._shards]
        if self._merged is not None:
            pending.append(self._merged)
        if self._overflow_dev is not None:
            pending.append(self._overflow_dev[1])
        jax.block_until_ready(pending)
        # already blocking on the host: finish the saturation-flag read
        # too, so the epoch's first query skips even that device->host
        # transfer
        if self._merged is not None and self._merged_epoch == self._epoch:
            self._refresh_overflow(self._merged)

    def _stamp_absorb_time(self):
        """The cache reflects THIS epoch (absorb-time maintenance)."""
        self._merged_epoch = self._epoch
        self._merged_base = list(self._shard_epochs)
        self.merge_stats["absorb_time"] += 1
        # dispatch the tiny all(valid) saturation reduction NOW, async —
        # the epoch's first query then reads an already-computed scalar
        # instead of paying a dispatch + device sync on its critical path
        # (the absorb itself still never blocks on it)
        self._overflow_dev = (self._epoch,
                              multisketch_overflow(self._merged))

    def set_shard(self, shard: int, sketch: MultiSketch):
        """Install a prebuilt slab (a collector's state, a checkpointed
        sketch, a slab wired from another job) as one shard's residency.
        The slab is COPIED in: a later absorb on this shard donates the
        resident buffers, and the caller's handle must stay valid.

        Replacing a shard's content is NON-MONOTONE (the old contribution
        may vanish), so the cached merged slab is dropped entirely — the
        next query takes the full re-merge path, never the delta fold."""
        self._shards[shard] = jax.tree.map(jnp.copy, sketch)
        self._epoch += 1
        self._shard_epochs[shard] = self._epoch
        self._shard_live[shard] = True
        self._drop_merged_cache()
        self._update_gauges()

    def shard_slab(self, shard: int) -> MultiSketch:
        """Shard ``shard``'s resident slab, by REFERENCE — the hand-off
        read half: a scale-out rebalance moves a shard between hosts as
        ``target.set_shard(s, source.shard_slab(s))`` (the receiving
        ``set_shard`` copies, so the transfer is a bit-exact snapshot)
        then ``source.clear_shard(s)``. Callers who hold the reference
        past this engine's next mutation of the shard must copy it first:
        a later absorb donates the resident buffers."""
        return self._shards[shard]

    def shard_live(self, shard: int) -> bool:
        """Whether ``shard`` holds data (False: parked on the inert empty
        slab — never absorbed into, GC'd away, or handed off)."""
        return bool(self._shard_live[shard])

    def clear_shard(self, shard: int):
        """Park one shard back on the shared inert slab — the hand-off
        release half (see ``shard_slab``): after the receiving host has
        copied the slab in, the source host drops its residency so the
        shard is owned exactly once across the group. NON-MONOTONE (the
        shard's contribution leaves this engine's union), so the cached
        merged slab is dropped — next query takes the full path."""
        self._shards[shard] = self._empty
        self._epoch += 1
        self._shard_epochs[shard] = self._epoch
        self._shard_live[shard] = False
        self._drop_merged_cache()
        self._update_gauges()

    def add_shard(self, sketch: MultiSketch):
        """Append a prebuilt slab as a NEW shard (copied in, like
        ``set_shard``) — cross-job fan-in: slabs restored from another
        job's checkpoint merge lazily with the resident state. A new shard
        only ADDS data: under ``absorb_time`` a current cache absorbs the
        new slab in this same epoch (the delta fold); otherwise the next
        query folds just the new slab into the cached merge."""
        maintain = (self.absorb_time and self._merged is not None
                    and self._merged_epoch == self._epoch
                    and self._merged_base is not None
                    and self.spec.cap >= self.spec.default_capacity())
        self._shards.append(jax.tree.map(jnp.copy, sketch))
        self._epoch += 1
        self._shard_epochs.append(self._epoch)
        self._shard_live.append(True)
        if maintain:
            merged = self._merged
            if (self._merged_handed_out or merged is self._empty
                    or any(merged is s for s in self._shards)):
                merged = jax.tree.map(jnp.copy, merged)
                self._merged_handed_out = False
            # the new slab is the whole delta; its buffers stay resident
            # (absorb_slabs donates only the state side)
            self._merged = multisketch_absorb_slabs(
                merged, sketch.keys, sketch.weights, sketch.valid,
                spec=self.spec, use_kernels=self.use_kernels)
            self._stamp_absorb_time()
        self._maybe_auto_gc()
        self._update_gauges()

    def load_stacked(self, stacked: MultiSketch):
        """Adopt a stacked batch of per-shard slabs (leaves [m, ...], e.g.
        from ``launch.summary.sharded_multisketch_shards``) as the resident
        state — the merge stays lazy until the first query. Wholesale
        replacement: the merged-slab cache is dropped (full path next) and
        the adopted layout becomes the new un-truncatable base layout."""
        m = stacked.keys.shape[0]
        self._shards = [jax.tree.map(lambda x, i=i: x[i], stacked)
                        for i in range(m)]
        self._min_shards = m
        self._epoch += 1
        self._shard_epochs = [self._epoch] * m
        self._shard_live = [True] * m
        self._drop_merged_cache()
        self._update_gauges()

    def _drop_merged_cache(self):
        self._merged = None
        self._merged_epoch = -1
        self._merged_base = None
        self._merged_handed_out = False

    def _update_gauges(self):
        """Host-side residency gauges (no device sync): live shard count
        and device bytes actually resident — shared/aliased buffers (the
        inert slab, the single-shard merged alias) counted once."""
        self.merge_stats["live_shards"] = int(sum(self._shard_live))
        seen: set = set()
        total = 0
        slabs = list(self._shards) + [self._empty]
        if self._merged is not None:
            slabs.append(self._merged)
        for sk in slabs:
            for leaf in sk:
                if id(leaf) not in seen:
                    seen.add(id(leaf))
                    total += int(getattr(leaf, "nbytes", 0))
        self.merge_stats["bytes_resident"] = total

    # -- shard lifecycle (GC / spill) ---------------------------------------
    def _maybe_auto_gc(self):
        if (self.gc_max_live is not None
                and sum(self._shard_live) > self.gc_max_live):
            self.gc(max_live=self.gc_max_live)

    def gc_plan(self, max_live: Optional[int] = None,
                min_age: Optional[int] = None) -> list:
        """Victim shard indices a ``gc`` with these water-marks would merge
        into the base slab, oldest (by last-absorb epoch) first. Pure —
        serving tiers call this to WAL a deterministic victim list before
        applying (``launch.pool``). Defaults to the engine's auto water-mark
        when neither bound is given."""
        if max_live is None and min_age is None:
            max_live = self.gc_max_live
        if len(self._shards) <= 1:
            return []
        cand = sorted((i for i in range(1, len(self._shards))
                       if self._shard_live[i]),
                      key=lambda i: (self._shard_epochs[i], i))
        vict: set = set()
        if min_age is not None:
            vict = {i for i in cand
                    if self._epoch - self._shard_epochs[i] >= int(min_age)}
        if max_live is not None:
            target = max(int(max_live), 1)
            n_live = len(cand) + (1 if self._shard_live[0] else 0)
            for i in cand:                   # age order: evict oldest first
                if n_live - len(vict) <= target:
                    break
                vict.add(i)
        return sorted(vict)

    def gc(self, max_live: Optional[int] = None,
           min_age: Optional[int] = None,
           spill_dir: Optional[str] = None) -> list:
        """Merge cold shards into the compacted base slab (shard 0).

        ``max_live`` bounds the LIVE shard count (oldest evicted first);
        ``min_age`` evicts every shard idle for that many epochs. Victims
        are folded into the base (one delta fold — exact by threshold
        closure at non-truncating capacity, so the union, and every query
        answer, is bit-identical to keeping the shards separate), then
        parked on the shared inert slab; trailing dead shards beyond the
        construction layout are dropped. With ``spill_dir`` the victim
        slabs are first persisted through the checkpoint manager
        (``spill``) so they can be re-adopted later via ``from_checkpoint``
        + ``add_shard``. Returns the victim indices merged."""
        return self.gc_apply(self.gc_plan(max_live, min_age),
                             spill_dir=spill_dir)

    def gc_apply(self, victims, spill_dir: Optional[str] = None) -> list:
        """Apply a GC merge to an explicit victim list (``gc_plan`` output
        or a WAL-replayed directive — serving recovery must reproduce the
        recorded decision, not re-plan it)."""
        victims = sorted({int(i) for i in victims})
        if not victims:
            return []
        if victims[0] < 1 or victims[-1] >= len(self._shards):
            raise ValueError(f"gc victims {victims} out of range "
                             f"(1..{len(self._shards) - 1})")
        # a cache that is current stays current: a GC merge moves data
        # between shards but never changes the union, so the merged slab
        # is re-stamped across the epoch bump instead of invalidated
        cache_current = (self._merged is not None
                         and self._merged_epoch == self._epoch
                         and self._merged_base is not None)
        if spill_dir is not None:
            self.spill(spill_dir, victims)
        base = self._shards[0]
        if base is self._empty or (self._merged is not None
                                   and self._merged is base):
            # the donated base fold must own its buffers
            base = jax.tree.map(jnp.copy, base)
        if len(victims) == 1:
            d = self._shards[victims[0]]
            dk, dw, dv = d.keys, d.weights, d.valid
        else:
            dk = jnp.stack([self._shards[i].keys for i in victims])
            dw = jnp.stack([self._shards[i].weights for i in victims])
            dv = jnp.stack([self._shards[i].valid for i in victims])
        self._shards[0] = multisketch_absorb_slabs(
            base, dk, dw, dv, spec=self.spec, use_kernels=self.use_kernels)
        for i in victims:
            self._shards[i] = self._empty
            self._shard_live[i] = False
        self._epoch += 1
        self._shard_epochs[0] = self._epoch
        self._shard_live[0] = True
        for i in victims:
            self._shard_epochs[i] = self._epoch
        while (len(self._shards) > max(self._min_shards, 1)
               and not self._shard_live[-1]
               and self._shards[-1] is self._empty):
            self._shards.pop()
            self._shard_epochs.pop()
            self._shard_live.pop()
        self.merge_stats["gc_merges"] += 1
        self.last_gc_epoch = self._epoch
        if cache_current:
            self._merged_epoch = self._epoch
            self._merged_base = list(self._shard_epochs)
        # stale caches stay stale: base + victims now read as dirty, and
        # the delta fold stays exact (the base contains the victims' data)
        self._update_gauges()
        return victims

    def spill(self, directory: str, shards) -> int:
        """Persist the given shards' slabs through ckpt.manager (atomic,
        crc'd) — the evict-to-disk hook a GC uses before parking victims.
        The saved step is ``from_checkpoint``-compatible: restoring the
        spill directory rebuilds an engine over exactly the spilled slabs,
        whose merged slab can be re-adopted via ``add_shard``."""
        from repro.ckpt.manager import CheckpointManager
        from repro.core.multi_sketch import spec_to_meta
        shards = [int(i) for i in shards]
        mgr = CheckpointManager(directory)
        step = max(mgr.list_steps(), default=-1) + 1
        mgr.save(step, {"shards": [self._shards[i] for i in shards]},
                 extra_meta={"multisketch_spec": spec_to_meta(self.spec),
                             "num_shards": len(shards),
                             "spilled_from": shards,
                             "spill_epoch": self._epoch})
        return step

    @classmethod
    def from_sharded(cls, spec: MultiSketchSpec, mesh, keys, weights,
                     active=None, axis: str = "data", **kw
                     ) -> "SegmentQueryEngine":
        """Build per-shard slabs over mesh-sharded data (local selection
        only — no replicated merge) and hold them resident."""
        from repro.launch.summary import sharded_multisketch_shards
        stacked = sharded_multisketch_shards(spec, mesh, keys, weights,
                                             active, axis=axis)
        eng = cls(spec, shards=stacked.keys.shape[0], **kw)
        eng.load_stacked(stacked)
        return eng

    # -- checkpointing -----------------------------------------------------
    def save_checkpoint(self, directory: str, step: Optional[int] = None,
                        blocking: bool = True,
                        extra_meta: Optional[dict] = None):
        """Persist the resident per-shard slabs + the spec (as JSON extra
        metadata) through ckpt.manager — atomic, crc-checked, keep-last-k.
        The slabs are plain arrays, so the checkpoint is mesh- and
        job-agnostic: any process restores it with ``from_checkpoint`` and
        merges it with its own state (threshold closure keeps that exact).

        ``step`` defaults to one past the newest existing step — the
        manager treats an already-present step as saved and skips it, so
        re-saving an updated engine must mint a fresh step number.
        ``extra_meta``: caller-owned JSON-able entries merged into the
        stored metadata (e.g. the serving pool's applied WAL sequence) —
        engine keys win on collision.
        """
        from repro.ckpt.manager import CheckpointManager
        from repro.core.multi_sketch import spec_to_meta
        mgr = CheckpointManager(directory)
        if step is None:
            step = max(mgr.list_steps(), default=-1) + 1
        ex = dict(extra_meta or {})
        ex.update({"multisketch_spec": spec_to_meta(self.spec),
                   "num_shards": len(self._shards),
                   "b_quantum": self.b_quantum,
                   "chunk": self.chunk,
                   "max_delta": self.max_delta,
                   "shard_live": [bool(x) for x in self._shard_live],
                   "min_shards": self._min_shards,
                   "gc_max_live": self.gc_max_live,
                   "absorb_time": self.absorb_time})
        mgr.save(step, {"shards": list(self._shards)}, blocking=blocking,
                 extra_meta=ex)
        return mgr

    @classmethod
    def from_checkpoint(cls, directory: str,
                        use_kernels: Optional[bool] = None,
                        return_meta: bool = False):
        """Rebuild an engine from the newest intact checkpoint: the spec
        comes from the stored metadata, the per-shard slabs from the
        crc-verified arrays — BOTH from the SAME step, falling back step by
        step when one is corrupt (a newer save's spec must never be paired
        with an older save's slabs). Queries over the restored engine are
        bit-identical to the saved one's (the slabs ARE the state).

        ``return_meta=True`` -> ``(engine, extra)`` where ``extra`` is the
        restored step's OWN extra-metadata dict — callers recovering
        stateful context (e.g. the pool's applied WAL sequence) need it
        from the step actually restored, not the newest one written."""
        from repro.ckpt.manager import CheckpointManager
        from repro.core.multi_sketch import spec_from_meta
        mgr = CheckpointManager(directory)
        for step in reversed(mgr.list_steps()):
            try:
                _, meta = mgr.read_meta(step)
                ex = meta["extra"]
                spec = spec_from_meta(ex["multisketch_spec"])
                num_shards = int(ex["num_shards"])
            except (FileNotFoundError, KeyError, ValueError, TypeError):
                continue
            template = {"shards": [multisketch_empty(spec)
                                   for _ in range(num_shards)]}
            state = mgr.restore_step(step, template)
            if state is None:
                continue
            md = ex.get("max_delta")
            gml = ex.get("gc_max_live")
            eng = cls(spec, shards=num_shards,
                      b_quantum=int(ex.get("b_quantum", 16)),
                      chunk=int(ex.get("chunk", 256)),
                      use_kernels=use_kernels,
                      max_delta=None if md is None else int(md),
                      absorb_time=bool(ex.get("absorb_time", True)),
                      gc_max_live=None if gml is None else int(gml))
            eng._shards = [MultiSketch(*(jnp.asarray(x) for x in s))
                           for s in state["shards"]]
            eng._epoch += 1
            eng._shard_epochs = [eng._epoch] * num_shards
            live = ex.get("shard_live")
            eng._shard_live = ([bool(x) for x in live]
                               if live is not None and len(live) == num_shards
                               else [True] * num_shards)
            eng._min_shards = int(ex.get("min_shards", num_shards))
            eng._update_gauges()
            return (eng, ex) if return_meta else eng
        raise FileNotFoundError(
            f"no intact checkpoint restorable under {directory}")

    # -- lazy merge-on-demand ----------------------------------------------
    def _dirty_shards(self) -> Optional[list]:
        """Shard indices mutated since the cached merge, or None when the
        cache can't seed an incremental fold (no cache / non-monotone
        history / truncating capacity, where delta != full bit-for-bit)."""
        if (self._merged is None or self._merged_base is None
                or self.spec.cap < self.spec.default_capacity()):
            return None
        base = self._merged_base
        return [i for i in range(len(self._shards))
                if i >= len(base) or self._shard_epochs[i] > base[i]]

    def _incremental_eligible(self, dirty: Optional[list]) -> bool:
        if dirty is None or not dirty:
            return False
        limit = (len(self._shards) - 1 if self.max_delta is None
                 else self.max_delta)
        return len(dirty) <= max(limit, 0)

    def _materialize_merged(self) -> MultiSketch:
        """The merged slab, maintained at most once per epoch: a cache hit,
        an INCREMENTAL delta fold (absorb the dirty shards' slabs into the
        cached merged slab — donated buffers, exact by threshold closure,
        bit-identical to the full path), or the full stacked re-merge."""
        if self._merged_epoch == self._epoch:
            self.merge_stats["hit"] += 1
            return self._refresh_overflow(self._merged)
        dirty = self._dirty_shards()
        if self._incremental_eligible(dirty):
            merged = self._merged
            if self._merged_handed_out or any(
                    merged is s for s in self._shards):
                # the cached slab is visible outside the engine (a caller
                # handle, or the single-shard alias of a live shard) — the
                # delta fold donates its buffers, so re-point at fresh ones
                merged = jax.tree.map(jnp.copy, merged)
                self._merged_handed_out = False
            if len(dirty) == 1:
                d = self._shards[dirty[0]]
                dk, dw, dv = d.keys, d.weights, d.valid
            else:
                # stack only the three leaves the delta fold consumes —
                # probs/seeds/member/aux/taus are recomputed by the
                # re-selection and would be copied just to be discarded
                dk = jnp.stack([self._shards[i].keys for i in dirty])
                dw = jnp.stack([self._shards[i].weights for i in dirty])
                dv = jnp.stack([self._shards[i].valid for i in dirty])
            self._merged = multisketch_absorb_slabs(
                merged, dk, dw, dv, spec=self.spec,
                use_kernels=self.use_kernels)
            self.merge_stats["incremental"] += 1
        elif len(self._shards) == 1:
            self._merged = self._shards[0]
            self.merge_stats["full"] += 1
        else:
            self._merged = _full_remerge(
                self._shards, spec=self.spec,
                use_kernels=self.use_kernels)
            self.merge_stats["full"] += 1
        self._merged_epoch = self._epoch
        self._merged_base = list(self._shard_epochs)
        return self._refresh_overflow(self._merged)

    def _refresh_overflow(self, sk: MultiSketch) -> MultiSketch:
        """Refresh the saturation flag at most once per epoch, at QUERY
        time — ``multisketch_overflow`` syncs the device, and absorb-time
        maintenance must not pay that sync on every fold. Maintained
        epochs pre-dispatched the reduction (``_stamp_absorb_time``), so
        the host read here usually lands on a finished scalar."""
        if self._overflow_epoch != self._epoch:
            pre = self._overflow_dev
            dev = (pre[1] if pre is not None and pre[0] == self._epoch
                   else multisketch_overflow(sk))
            self.merge_stats["overflow"] = bool(dev)
            self._overflow_epoch = self._epoch
        return sk

    @property
    def merged(self) -> MultiSketch:
        """The merged slab, materialized at most once per epoch. The handle
        stays valid across later updates: the next incremental fold donates
        only engine-owned buffers (a handed-out slab is re-pointed first,
        same discipline as ``absorb`` on the single-shard alias)."""
        sk = self._materialize_merged()
        self._merged_handed_out = True
        return sk

    # -- queries -----------------------------------------------------------
    def query_many(self, fs: Optional[Sequence[StatFn]] = None,
                   predicates=EVERYTHING) -> np.ndarray:
        """Q(f_i, H_b) for every objective x predicate -> float [|F|, B].

        ONE fused launch over the merged slab regardless of B and |F|
        (kernels.segquery); B is padded to ``b_quantum`` with never-matching
        predicates so same-bucket batches share one compiled executable.
        """
        fs = (tuple(f for f, _ in self.spec.objectives) if fs is None
              else tuple(fs))
        # internal access: queries read the slab without marking it handed
        # out, so the next delta fold may still donate its buffers
        return multisketch_query_many(self._materialize_merged(), fs,
                                      predicates, b_quantum=self.b_quantum,
                                      use_kernels=self.use_kernels)

    def query(self, f: StatFn, predicate: SegmentPredicate = EVERYTHING
              ) -> float:
        """Single Q(f, H) — routed through the batched path (same compiled
        executable as any 1-query batch of this objective)."""
        return float(self.query_many((f,), predicate)[0, 0])
