import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Placeholder host devices exist ONLY for this dry-run entry point.
#
# Second flag: XLA:CPU's while-loop-invariant-code-motion hoists the
# backward-pass bf16->f32 convert of the SAVED-ACTIVATION stack out of the
# layer loop, materializing a duplicate f32 copy of all remat checkpoints
# (~2x activation memory, CPU-backend artifact — XLA:TPU buffer assignment
# is HBM-aware). Disable it so memory_analysis reflects the real plan.
os.environ["XLA_FLAGS"] += " --xla_disable_hlo_passes=while-loop-invariant-code-motion"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this proves the distribution config is coherent
without hardware: jit(step).lower(**input_specs).compile() must succeed on
the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh. We record
memory_analysis (fits-per-device), XLA cost_analysis, and our own
trip-count-corrected HLO cost model (launch/hlo_cost.py) for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out-dir experiments/dryrun]
"""
import argparse
import json
import subprocess
import sys
import time
import traceback


# Baseline grad-accumulation factors chosen so the per-device saved-
# activation floor (L x B_local x S x D x 2B for remat-per-layer) fits HBM.
# Recorded with each cell; hillclimbing may revisit.
DEFAULT_MICROBATCH = {
    "deepseek-67b": 16, "internvl2-76b": 16, "falcon-mamba-7b": 4,
    "zamba2-2.7b": 4, "phi3-mini-3.8b": 2, "qwen2-moe-a2.7b": 4,
    "granite-moe-1b-a400m": 2, "hubert-xlarge": 2, "gemma-2b": 2,
    "qwen2-1.5b": 2,
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatch: int = 0, overrides: str = "",
             compress: bool = False) -> dict:
    import jax
    from repro.configs.registry import get_config, sub_quadratic
    from repro.configs.shapes import SHAPES, cell_is_runnable
    from repro.launch import hlo_cost, steps as St
    from repro.launch.mesh import make_production_mesh, mesh_context
    from repro.optim import adamw

    cfg = get_config(arch)
    if overrides:
        import dataclasses
        kv = dict(item.split("=", 1) for item in overrides.split(","))
        typed = {}
        for k, v in kv.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(eval(v)) if not isinstance(cur, str) else v
        cfg = dataclasses.replace(cfg, **typed)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_runnable(cfg.family, shape, sub_quadratic(cfg))
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "family": cfg.family}
    if not ok:
        result.update(status="skipped", reason=reason)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    if microbatch == 0 and shape.kind == "train":
        microbatch = DEFAULT_MICROBATCH.get(arch, 1)
    t0 = time.time()
    with mesh_context(mesh):
        if shape.kind == "train":
            opt = adamw.OptConfig()
            step, _ = St.make_train_step(
                cfg, opt, mesh, shape=shape,
                microbatch=microbatch if microbatch > 1 else None,
                compress=dict(k=512) if compress else None)
            state_shapes, _ = St.abstract_state(cfg)
            lowered = step.lower(state_shapes, St.input_specs(cfg, shape))
        elif shape.kind == "prefill":
            step, _ = St.make_prefill_step(cfg, mesh, shape=shape)
            params_shapes, _ = St.abstract_params(cfg)
            lowered = step.lower(params_shapes, St.input_specs(cfg, shape))
        else:  # decode
            step, _, _ = St.make_serve_step(cfg, shape, mesh)
            params_shapes, _ = St.abstract_params(cfg)
            cache_shapes = St.cache_abstract(cfg, shape)
            lowered = step.lower(params_shapes,
                                 St.input_specs(cfg, shape)["tokens"],
                                 cache_shapes,
                                 jax.ShapeDtypeStruct((), jax.numpy.int32))
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mine = hlo_cost.analyze(hlo)
    print(f"[{arch} x {shape_name} x {result['mesh']}] "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print("memory_analysis:", {
        k: getattr(mem, k, None) for k in
        ("argument_size_in_bytes", "output_size_in_bytes",
         "temp_size_in_bytes", "generated_code_size_in_bytes")})
    print("cost_analysis flops (uncorrected):", cost.get("flops"))
    print("hlo_cost (trip-corrected):", {k: v for k, v in mine.items()
                                         if k != "coll_ops"})
    print("collectives:", mine["coll_ops"])

    result.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory={k: int(getattr(mem, k, 0) or 0) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")},
        xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed",
                                           "transcendentals")
                  if k in cost},
        hlo_cost=mine,
        microbatch=microbatch, overrides=overrides, compress=compress,
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--overrides", default="",
                    help="cfg overrides k=v,k=v (perf iterations)")
    ap.add_argument("--compress", action="store_true",
                    help="sampled cross-pod gradient exchange (train cells)")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    if args.all:
        from repro.configs.registry import list_archs
        from repro.configs.shapes import SHAPES
        os.makedirs(args.out_dir, exist_ok=True)
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        jobs = [(a, s, mp) for a in list_archs() for s in SHAPES
                for mp in meshes]
        failures = 0
        for a, s, mp in jobs:
            tag = f"{a}__{s}__{'mp' if mp else 'sp'}"
            out = os.path.join(args.out_dir, tag + ".json")
            if os.path.exists(out):
                print("skip (exists):", tag)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--out", out]
            if mp:
                cmd.append("--multi-pod")
            print(">>>", tag, flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures += 1
            except subprocess.TimeoutExpired:
                failures += 1
                with open(out, "w") as f:
                    json.dump({"arch": a, "shape": s,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "timeout"}, f)
        print("done; failures:", failures)
        sys.exit(1 if failures else 0)

    try:
        result = run_cell(args.arch, args.shape, args.multi_pod,
                          args.microbatch, args.overrides, args.compress)
    except Exception:
        result = {"arch": args.arch, "shape": args.shape,
                  "mesh": "2x16x16" if args.multi_pod else "16x16",
                  "status": "error", "error": traceback.format_exc()}
        print(result["error"], file=sys.stderr)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    sys.exit(0 if result.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
