"""Serving driver: prefill + batched decode behind the fault-tolerant pool.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 16 --gen 16

Demonstrates the inference path the decode_* dry-run cells lower: a prompt
batch is prefilled (building the KV/SSM cache), then tokens are decoded
step-by-step with greedy sampling. Request-level statistics (prompt length,
generated tokens) flow through the multi-tenant ``EnginePool``
(launch.pool): admission-queued, quarantined per row, answered with the
degradation ladder's staleness/overflow labels — the dashboard path a real
deployment serves from, not a bare collector.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config, list_archs
from repro.core import (EVERYTHING, SUM, COUNT, MultiSketchSpec,
                        hash_fraction, thresh)
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.launch.pool import EnginePool
from repro.models import model as Mod


def _positive_int(v: str) -> int:
    i = int(v)
    if i < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {i}")
    return i


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=_positive_int, default=4)
    ap.add_argument("--prompt-len", type=_positive_int, default=16)
    ap.add_argument("--gen", type=_positive_int, default=16,
                    help="tokens to generate (>= 1; 1 = prefill-only "
                         "argmax, no decode steps)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(args.seed)
    max_len = args.prompt_len + args.gen

    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                     0, cfg.vocab_size)
        batch = {"tokens": prompts}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                key, (args.batch, cfg.frontend_tokens, cfg.d_model),
                jnp.bfloat16)

        t0 = time.time()
        logits, cache = Mod.prefill(params, cfg, batch)
        cache = Mod.grow_cache(cfg, cache, args.gen)  # room for decode steps
        t_prefill = time.time() - t0

        decode = jax.jit(lambda p, t, c, i: Mod.serve_step(p, cfg, t, c, i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        t0 = time.time()
        idx0 = args.prompt_len
        for t in range(args.gen - 1):
            logits, cache = decode(params, tok, cache, jnp.int32(idx0 + t))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        gen = jnp.stack(outs, 1)

        print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f} ms")
        if args.gen > 1:   # gen==1 decodes zero steps: no per-token rate
            print(f"decode {args.gen-1} steps: "
                  f"{t_decode*1e3/(args.gen-1):.1f} ms/token")
        else:
            print("decode 0 steps (prefill-only argmax)")
        print("generated token ids (first row):",
              np.asarray(gen[0])[:12].tolist())

        # request telemetry through the fault-tolerant serving tier: one
        # named stream per tenant behind the pool's admission queue —
        # ingest is per-row quarantined, the dashboard batch coalesces
        # into ONE fused segment-query launch, and every answer carries
        # its degradation-ladder label (FRESH/STALE) + overflow flag.
        pool = EnginePool(queue_depth=64)
        pool.create_stream("requests", MultiSketchSpec(
            objectives=((SUM, 64), (COUNT, 64), (thresh(16.0), 64))))
        receipt = pool.absorb(
            "requests", np.arange(args.batch),
            np.full(args.batch, float(args.prompt_len + args.gen)))
        fut = pool.submit("requests", (SUM, COUNT, thresh(16.0)),
                          (EVERYTHING, hash_fraction(0.5, salt=1)))
        pool.pump()
        resp = fut.result(timeout=30.0)
        stats = resp.values
        print(f"[pool] stream=requests status={resp.status} "
              f"lag={resp.epoch_lag} overflow={resp.overflow} "
              f"quarantined={receipt.quarantined}")
        print("[telemetry] est total tokens served:", float(stats[0, 0]))
        print("[telemetry] est requests:", float(stats[1, 0]))
        print("[telemetry] est requests >= 16 tokens:", float(stats[2, 0]))
        print("[telemetry] est tokens, 50% coordinated key sample:",
              float(stats[0, 1]))

        # request-shape clustering: the metric-domain tier over the same
        # request log — a resident sampled point slab scored by the fused
        # service-cost kernel (launch.cluster); a sharded server absorbs
        # per-replica request features and answers capacity-planning
        # queries (k typical request shapes, coverage radii) from the
        # sample alone.
        from repro.launch.cluster import ClusterEngine, local_search
        gen_np = np.asarray(gen)
        feats = np.stack(
            [np.full(args.batch, args.prompt_len + args.gen, np.float32),
             np.array([len(np.unique(r)) for r in gen_np], np.float32)], 1)
        ceng = ClusterEngine(dim=2, k=16, mu=2.0,
                             n_anchors=min(4, args.batch), seed=args.seed)
        ceng.absorb(feats)
        res = local_search(ceng, k=min(2, args.batch), rounds=4, n_cand=8)
        print("[cluster] request-shape centers:",
              np.round(res.centers, 2).tolist())
        print("[cluster] est k-means service cost:", round(res.est_cost, 3))


if __name__ == "__main__":
    main()
