"""Production mesh builders.

Functions (not module constants) so importing never touches jax device state.
Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an outer data-parallel axis whose collectives cross DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
