"""Production mesh builders.

Functions (not module constants) so importing never touches jax device state.
Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an outer data-parallel axis whose collectives cross DCN.
"""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def mesh_context(mesh):
    """Version-compatible ambient-mesh context.

    ``jax.set_mesh`` (the context-manager form) only exists in newer jax;
    on older versions the legacy ``Mesh.__enter__`` resource context is the
    equivalent. All drivers/tests enter meshes through this helper so the
    repo runs on both. Explicit NamedShardings built from ``mesh`` keep
    working either way — the ambient mesh only backs convenience APIs.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        with set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names=None,
                     check=False):
    """Version-compatible ``shard_map``.

    Newer jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``; older
    versions have ``jax.experimental.shard_map.shard_map(..., auto=,
    check_rep=)`` where ``auto`` is the complement of ``axis_names``. The
    repo's manual-collective code (distopt, sharded summaries) goes through
    this shim so both APIs work.
    """
    names = frozenset(axis_names) if axis_names else frozenset(mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=names,
                             check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    if (names != frozenset(mesh.axis_names)
            and not jax.config.jax_use_shardy_partitioner):
        # Partially-manual regions crash the legacy GSPMD partitioner
        # (hlo_sharding_util IsManualSubgroup check); the code targets sdy
        # semantics, which old jax only applies behind this flag. The flag
        # must still be set when the wrapped fn COMPILES (not just traces),
        # so it cannot be scoped to this call — flip it process-wide, once,
        # loudly. New jax (jax.shard_map present) never takes this path.
        import warnings
        warnings.warn(
            "shard_map_compat: enabling jax_use_shardy_partitioner "
            "process-wide — legacy jax cannot partition partially-manual "
            "shard_map regions under GSPMD; subsequent jit compilations "
            "in this process will use the shardy partitioner.",
            stacklevel=2)
        jax.config.update("jax_use_shardy_partitioner", True)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=frozenset(mesh.axis_names) - names)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
