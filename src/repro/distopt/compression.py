"""Sampled gradient exchange — the paper's technique attacking the
COLLECTIVE roofline term (DESIGN.md §2.1).

Standard multi-pod data parallelism all-reduces dense gradients across the
"pod" axis (cross-DCN: the slowest link). Here each DEVICE communicates a
FIXED-SIZE multi-objective bottom-k sample of ITS SHARD of the pod-local
gradient:

  keys    = (pod, device, coordinate) — distinct across pods/devices, so the
            union of per-shard samples is a valid weighted data set (§2.5
            composability — the merge is exact for the union's estimator);
  weights = |g_i| (normalized per shard);
  F       = {(sum, k), (cap_c, k), (count, k)} — one coordinated sample
            serves the gradient estimate (sum), heavy-hitter-robust mass
            (cap), and support statistics simultaneously (Thm 3.1);
  wire    = 3k slots of (idx, val, prob) per device pair over DCN;
  merge   = own pod's shard stays EXACT; remote pods' contributions are HT
            estimates (Eq. 5) — unbiased for the pod-mean gradient with
            strictly less variance than sampling both sides.

Structure: two sibling shard_maps (sdy forbids pod collectives nested under
a pod-manual region):
  sm1  manual{pod}:             forward/backward with auto TP inside; the
                                returned grads are pod-VARYING (declared
                                replicated with check_vma=False — consumed
                                only by sm2).
  sm2  manual{pod,data,model}:  per-device-shard sampling, pod all_gather of
                                sketches, HT merge. Small leaves go dense
                                (pmean) — their bytes are negligible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cap, COUNT, SUM
from repro.core.bottomk import conditional_prob, f_seed, kth_and_tau
from repro.core.hashing import uniform01

_OBJECTIVES = lambda cap_frac: ((SUM, "sum"), (cap(cap_frac), "cap"),
                                (COUNT, "count"))


def _sample_leaf(g, k: int, seed, cap_frac: float, scheme: str = "ppswor"):
    """Multi-objective bottom-k sample of one (shard of a) gradient leaf.

    Returns (idx [3k], val [3k], prob [3k], valid [3k]) — fixed wire size;
    the union S^(F) occupies a random prefix of the slots (paper §3.3:
    E|S^(F)| <= sum k_f).
    """
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    w = jnp.abs(flat)
    wmax = jnp.maximum(jnp.max(w), 1e-30)
    wn = w / wmax                                   # weights in (0,1]
    active = wn > 0
    u = uniform01(jnp.arange(n, dtype=jnp.int32), seed)

    kk = min(k, n)
    # Batched over the (static) 3 objectives: stack the shared-u_x seeds
    # [3, n], then ONE top_k(k+1) scan yields every kth and tau — no
    # per-objective scans, no second pass for the threshold.
    objs = _OBJECTIVES(cap_frac)
    seeds_F = jnp.stack([f_seed(wn, active, f, u, scheme) for f, _ in objs])
    fv_F = jnp.stack([jnp.where(active, f(wn), 0.0) for f, _ in objs])
    kth, tau = kth_and_tau(seeds_F, kk)
    member_F = (seeds_F <= kth[:, None]) & jnp.isfinite(seeds_F)
    p_F = jnp.where(member_F,
                    conditional_prob(fv_F, tau[:, None], scheme), 0.0)
    member = member_F.any(axis=0)
    prob = p_F.max(axis=0)                          # p^(F) = max_f p^(f)

    # compact members into 3k fixed slots (members first)
    slots = 3 * kk
    order = jnp.argsort(~member)                    # members first
    take = order[:slots]
    valid = member[take]
    return (jnp.where(valid, take, 0).astype(jnp.int32),
            jnp.where(valid, flat[take], 0.0),
            jnp.where(valid, prob[take], 1.0),
            valid)


def _merge_leaf(idx, val, prob, valid, n, npods):
    """HT-estimate the mean gradient from gathered per-pod samples
    (all-sampled variant; benchmarks use this single-pod)."""
    contrib = jnp.where(valid, val / jnp.maximum(prob, 1e-30), 0.0)
    dense = jnp.zeros((n,), jnp.float32)
    dense = dense.at[idx.reshape(-1)].add(contrib.reshape(-1))
    return dense / npods


def compressed_grads_fn(compute_grads, mesh, *, axis: str = "pod",
                        k: int = 512, cap_frac: float = 0.01, seed: int = 17,
                        min_size: int = 65536):
    """Wrap (params, batch) -> (loss, metrics, grads) so the cross-POD
    gradient reduction is the paper's sampled exchange instead of a dense
    all-reduce. Returns None on single-pod meshes."""
    if axis not in mesh.axis_names:
        return None
    npods = mesh.shape[axis]
    all_axes = set(mesh.axis_names)

    def wrapped(params, batch, step, param_specs):
        # ---- sm1: pod-local grads (auto TP/DP inside) -------------------
        def grads_body(params, batch):
            loss, metrics, grads = compute_grads(params, batch)
            return (jax.lax.pmean(loss, axis),
                    jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics),
                    grads)  # pod-varying; consumed only by sm2

        bspec = jax.tree.map(lambda _: P(axis), batch)
        rep = jax.tree.map(lambda _: P(), params)
        loss, metrics, grads = jax.shard_map(
            grads_body, mesh=mesh,
            in_specs=(rep, bspec, ),
            out_specs=(P(), P(), rep),
            axis_names={axis}, check_vma=False)(params, batch)

        # ---- sm2: fully-manual sampled exchange -------------------------
        flat, treedef = jax.tree_util.tree_flatten(grads)
        flat_specs = jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P))

        def exchange(step_, *leaves):
            pod = jax.lax.axis_index(axis)
            out = []
            for j, g in enumerate(leaves):
                if g.size < min_size:
                    out.append(jax.lax.pmean(g, axis))
                    continue
                s = (jnp.uint32(seed) + jnp.uint32(j * 1_000_003)
                     + jnp.uint32(pod) * jnp.uint32(7919)
                     + step_.astype(jnp.uint32))
                flat_g = g.reshape(-1)
                n = flat_g.shape[0]
                idx, val, prob, valid = _sample_leaf(flat_g, k, s, cap_frac)
                gi = jax.lax.all_gather(idx, axis)
                gv = jax.lax.all_gather(val, axis)
                gp = jax.lax.all_gather(prob, axis)
                gm = jax.lax.all_gather(valid, axis)
                total = jnp.zeros((n,), jnp.float32)
                est_self = jnp.zeros((n,), jnp.float32)
                for p_ in range(npods):
                    contrib = jnp.where(
                        gm[p_], gv[p_] / jnp.maximum(gp[p_], 1e-30), 0.0)
                    est_p = jnp.zeros((n,), jnp.float32).at[gi[p_]].add(
                        contrib)
                    total = total + est_p
                    est_self = est_self + jnp.where(pod == p_, est_p, 0.0)
                dense = (total - est_self
                         + flat_g.astype(jnp.float32)) / npods
                out.append(dense.reshape(g.shape).astype(g.dtype))
            return tuple(out)

        specs = tuple(flat_specs)
        new_flat = jax.shard_map(
            exchange, mesh=mesh,
            in_specs=(P(),) + specs, out_specs=specs,
            axis_names=all_axes, check_vma=False)(step, *flat)
        grads = jax.tree_util.tree_unflatten(treedef, new_flat)
        return loss, metrics, grads

    return wrapped
