"""Sampled gradient exchange — the paper's technique attacking the
COLLECTIVE roofline term (DESIGN.md §2.1).

Standard multi-pod data parallelism all-reduces dense gradients across the
"pod" axis (cross-DCN: the slowest link). Here each DEVICE communicates a
FIXED-SIZE multi-objective bottom-k sample of ITS SHARD of the pod-local
gradient:

  keys    = (pod, device, coordinate) — distinct across pods/devices, so the
            union of per-shard samples is a valid weighted data set (§2.5
            composability — the merge is exact for the union's estimator);
  weights = |g_i| (normalized per shard);
  F       = {(sum, k), (cap_c, k), (count, k)} — one coordinated sample
            serves the gradient estimate (sum), heavy-hitter-robust mass
            (cap), and support statistics simultaneously (Thm 3.1);
  wire    = a fixed 3k-slot MultiSketch slab (core.multi_sketch wire
            format; keys/weights/probs/valid gathered, seeds/taus local)
            per device pair over DCN;
  merge   = own pod's shard stays EXACT; remote pods' contributions are HT
            estimates (Eq. 5) — unbiased for the pod-mean gradient with
            strictly less variance than sampling both sides.

Structure: two sibling shard_maps (sdy forbids pod collectives nested under
a pod-manual region):
  sm1  manual{pod}:             forward/backward with auto TP inside; the
                                returned grads are pod-VARYING (declared
                                replicated with check_vma=False — consumed
                                only by sm2).
  sm2  manual{pod,data,model}:  per-device-shard sampling, pod all_gather of
                                sketches, HT merge. Small leaves go dense
                                (pmean) — their bytes are negligible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cap, COUNT, SUM
from repro.core.multi_sketch import (MultiSketch, MultiSketchSpec,
                                     multisketch_select)
from repro.launch.mesh import shard_map_compat


def _leaf_spec(k: int, cap_frac: float, scheme: str) -> MultiSketchSpec:
    """The coordinated objective set F of the gradient exchange."""
    return MultiSketchSpec(
        objectives=((SUM, k), (cap(cap_frac), k), (COUNT, k)),
        scheme=scheme, capacity=3 * k)


def _sample_leaf(g, k: int, seed, cap_frac: float,
                 scheme: str = "ppswor") -> MultiSketch:
    """Multi-objective bottom-k sample of one (shard of a) gradient leaf,
    as a fixed-capacity MultiSketch wire slab (3k slots, members first).

    Selection is core.multi_sketch.multisketch_select (pure-XLA path: this
    runs inside a fully-manual shard_map, and the per-(step, pod) reseed is
    traced). The sketch's ``weights`` slab carries the SIGNED gradient
    entries — probabilities were computed from the normalized |g| weights —
    so the HT merge reads contributions directly off the wire. Aux slots
    are dropped: pods hold disjoint key spaces, so the exchange never
    re-selects across pods (§2.5 composability keeps the union estimator
    exact); only members carry HT mass.
    """
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    w = jnp.abs(flat)
    wmax = jnp.maximum(jnp.max(w), 1e-30)
    wn = w / wmax                                   # weights in (0,1]
    spec = _leaf_spec(min(k, n), cap_frac, scheme)
    keys = jnp.arange(n, dtype=jnp.int32)
    member, prob, _aux, seeds, taus = multisketch_select(
        spec, keys, wn, (wn > 0), use_kernels=False, seed=seed)

    # compact members into 3k fixed slots (members first)
    slots = spec.cap
    order = jnp.argsort(~member)                    # members first
    take = order[:slots]
    valid = member[take]
    return MultiSketch(
        keys=jnp.where(valid, take, -1).astype(jnp.int32),
        weights=jnp.where(valid, flat[take], 0.0),  # signed payload
        probs=jnp.where(valid, prob[take], 1.0),
        seeds=jnp.where(valid[None, :], seeds[:, take], jnp.inf),
        member=valid,
        aux=jnp.zeros_like(valid),
        valid=valid,
        taus=taus)


def _merge_leaf(idx, val, prob, valid, n, npods):
    """HT-estimate the mean gradient from gathered per-pod sketch slabs
    (all-sampled variant; benchmarks use this single-pod)."""
    contrib = jnp.where(valid, val / jnp.maximum(prob, 1e-30), 0.0)
    dense = jnp.zeros((n,), jnp.float32)
    dense = dense.at[jnp.maximum(idx, 0).reshape(-1)].add(contrib.reshape(-1))
    return dense / npods


def compressed_grads_fn(compute_grads, mesh, *, axis: str = "pod",
                        k: int = 512, cap_frac: float = 0.01, seed: int = 17,
                        min_size: int = 65536):
    """Wrap (params, batch) -> (loss, metrics, grads) so the cross-POD
    gradient reduction is the paper's sampled exchange instead of a dense
    all-reduce. Returns None on single-pod meshes."""
    if axis not in mesh.axis_names:
        return None
    npods = mesh.shape[axis]
    all_axes = set(mesh.axis_names)

    def wrapped(params, batch, step, param_specs):
        # ---- sm1: pod-local grads (auto TP/DP inside) -------------------
        def grads_body(params, batch):
            loss, metrics, grads = compute_grads(params, batch)
            return (jax.lax.pmean(loss, axis),
                    jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics),
                    grads)  # pod-varying; consumed only by sm2

        bspec = jax.tree.map(lambda _: P(axis), batch)
        rep = jax.tree.map(lambda _: P(), params)
        loss, metrics, grads = shard_map_compat(
            grads_body, mesh,
            in_specs=(rep, bspec, ),
            out_specs=(P(), P(), rep),
            axis_names={axis})(params, batch)

        # ---- sm2: fully-manual sampled exchange -------------------------
        flat, treedef = jax.tree_util.tree_flatten(grads)
        flat_specs = jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda x: isinstance(x, P))

        def exchange(step_, *leaves):
            pod = jax.lax.axis_index(axis)
            out = []
            for j, g in enumerate(leaves):
                if g.size < min_size:
                    out.append(jax.lax.pmean(g, axis))
                    continue
                s = (jnp.uint32(seed) + jnp.uint32(j * 1_000_003)
                     + jnp.uint32(pod) * jnp.uint32(7919)
                     + step_.astype(jnp.uint32))
                flat_g = g.reshape(-1)
                n = flat_g.shape[0]
                sk = _sample_leaf(flat_g, k, s, cap_frac)
                # ship the sketch's HT slabs (keys/weights/probs/valid);
                # seeds/taus are recomputable and stay pod-local
                gi = jax.lax.all_gather(sk.keys, axis)
                gv = jax.lax.all_gather(sk.weights, axis)
                gp = jax.lax.all_gather(sk.probs, axis)
                gm = jax.lax.all_gather(sk.valid, axis)
                total = jnp.zeros((n,), jnp.float32)
                est_self = jnp.zeros((n,), jnp.float32)
                for p_ in range(npods):
                    contrib = jnp.where(
                        gm[p_], gv[p_] / jnp.maximum(gp[p_], 1e-30), 0.0)
                    est_p = jnp.zeros((n,), jnp.float32).at[
                        jnp.maximum(gi[p_], 0)].add(contrib)
                    total = total + est_p
                    est_self = est_self + jnp.where(pod == p_, est_p, 0.0)
                dense = (total - est_self
                         + flat_g.astype(jnp.float32)) / npods
                out.append(dense.reshape(g.shape).astype(g.dtype))
            return tuple(out)

        specs = tuple(flat_specs)
        new_flat = shard_map_compat(
            exchange, mesh,
            in_specs=(P(),) + specs, out_specs=specs,
            axis_names=all_axes)(step, *flat)
        grads = jax.tree_util.tree_unflatten(treedef, new_flat)
        return loss, metrics, grads

    return wrapped
