"""AdamW with decoupled weight decay, global-norm clipping and a
warmup-cosine schedule. Native pytree implementation (no external deps);
optimizer state shards exactly like the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_frac
                         + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if hasattr(p, "shape") else jnp.zeros((), jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step_ + decay)
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gn, "lr": lr}
