"""Bottom-k (order) sampling: priority and ppswor (paper §2.2–§2.3).

f-seed(x) = r_x / f(w_x); the sample is the k keys with smallest f-seed and
the retained threshold tau = (k+1)-th smallest f-seed. Conditional inclusion
probabilities (paper Eq. 3):
    priority: p_x = min(1, f(w_x) * tau)
    ppswor:   p_x = 1 - exp(-f(w_x) * tau)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .funcs import StatFn
from .hashing import rank_of, uniform01

_INF = jnp.float32(jnp.inf)


def f_seed(weights, active, f: StatFn, u, scheme: str):
    """f-seed(x) = r_x / f(w_x); inactive or f(w)=0 keys get seed = +inf."""
    r = rank_of(u, scheme)
    fv = f(weights)
    ok = active & (fv > 0)
    return jnp.where(ok, r / jnp.maximum(fv, 1e-30), _INF)


class BottomK(NamedTuple):
    member: jnp.ndarray   # bool [n] — x in S (the k smallest f-seeds)
    prob: jnp.ndarray     # float32 [n] — conditional p_x for members, else 0
    tau: jnp.ndarray      # float32 [] — (k+1)-th smallest f-seed
    seeds: jnp.ndarray    # float32 [n] — the f-seeds (inf for inactive)


def kth_and_tau(x, k: int):
    """(k-th, (k+1)-th) smallest of x along the last axis — ONE top_k scan.

    Works batched: x [..., n] -> (kth [...], tau [...]). tau is +inf when
    n <= k (no (k+1)-th entry), matching the bottom-k convention that a
    sample holding every key has threshold +inf.
    """
    n = x.shape[-1]
    kk = min(k, n)
    vals = -jax.lax.top_k(-x, min(kk + 1, n))[0]
    kth = vals[..., kk - 1]
    tau = (vals[..., kk] if n > kk
           else jnp.full(x.shape[:-1], jnp.inf, jnp.float32))
    return kth, tau


def conditional_prob(fv, tau, scheme: str):
    """Eq. (3): Pr_{u~U[0,1]}[r/f(w) < tau]."""
    t = jnp.maximum(fv, 0.0) * tau
    if scheme == "priority":
        return jnp.minimum(1.0, t)
    # ppswor; tau may be +inf (fewer than k+1 active keys) -> p = 1.
    return jnp.where(jnp.isinf(t), 1.0, -jnp.expm1(-t))


def bottomk_sample(keys, weights, active, f: StatFn, k: int, scheme: str = "ppswor",
                   seed=0) -> BottomK:
    """Bottom-k sample w.r.t. f, with conditional inclusion probabilities.

    For member x the k-th smallest f-seed among OTHER keys equals tau (the
    global (k+1)-th smallest), which is exactly the conditioning the paper
    uses (§2.3).
    """
    u = uniform01(keys, seed)
    seeds = f_seed(weights, active, f, u, scheme)
    # kth and tau = (k+1)-th smallest seed from one top_k(k+1) scan;
    # tau = +inf when fewer than k+1 finite seeds.
    kth, tau = kth_and_tau(seeds, k)
    member = (seeds < kth) | ((seeds == kth) & jnp.isfinite(seeds))
    fv = jnp.where(active, f(weights), 0.0)
    p = jnp.where(member, conditional_prob(fv, tau, scheme), 0.0)
    return BottomK(member=member, prob=p, tau=tau, seeds=seeds)
