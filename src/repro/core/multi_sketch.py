"""MultiSketch: the mergeable fixed-capacity multi-objective summary.

This is the device-resident state + wire format for S^(F) ∪ Z of a
multi-objective bottom-k sample (paper §3.2/§3.3), replacing the ephemeral
per-call ``MultiBottomK`` wherever a sample must survive across batches
(streaming), across shards (``all_gather``) or across hosts (telemetry).

Wire format — a pytree of arrays with static half ``MultiSketchSpec``:

  keys    int32   [c]      key ids, -1 on empty slots
  weights float32 [c]      w_x (merged data sets: max over occurrences)
  probs   float32 [c]      p_x^(F) = max_f p_x^(f) for members, else 0
  seeds   float32 [nf, c]  per-objective f-seeds r_x / f(w_x) (+inf invalid)
  member  bool    [c]      x ∈ S^(F)
  aux     bool    [c]      x ∈ Z (per-objective threshold keys, see below)
  valid   bool    [c]      slot occupied
  taus    float32 [nf]     tau^(f,k_f): the (k_f+1)-th smallest f-seed

  spec (static, hashable, jit-static): objectives ((StatFn, k_f), ...),
  scheme ('ppswor' | 'priority'), hash seed, capacity.

Merge invariant (paper §3.3 composability): because every per-objective
sample shares u_x = hash(key, seed), S^(f,k_f) of a union of data sets is
contained in the union of the parts' S^(f,k_f); and the union's threshold
key (the arg of tau^(f)) has per-part seed rank <= k_f + 1, so it is a part
member OR a part threshold key. We therefore retain in Z the threshold key
of EVERY objective (<= |F| slots — a superset of the paper's
estimation-only Z, which keeps only thresholds of some member's most
forgiving objective). With that, re-running selection on the concatenated
retained keys of any parts reproduces the member set, probabilities AND
thresholds of the sample the union data set would have produced — exactly.
Hence ``absorb`` (streaming fold), ``merge`` and ``merge_stacked``
(post-all_gather) are all the same re-selection and agree with a one-shot
build over the concatenated data for any chunking and any order.

Capacity: |S^(F)| <= sum_f k_f (hard, each S^(f) holds k_f keys) and
|Z| <= |F|, so the default capacity sum_f k_f + |F| + 1 never truncates; a
truncated compaction drops lowest-weight aux slots first and voids the
exactness guarantee (detectable: multisketch_overflow()).

Selection reuses the PR 1 single-launch batched kernels
(fused_seeds_fvals + batched block-select) when ``use_kernels`` — the
default on the host-facing entry points; inside shard_map/manual-collective
regions callers pass use_kernels=False and get the identical pure-XLA path
(one stacked top_k), bit-compatible with the kernels.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .bottomk import conditional_prob, f_seed
from .funcs import StatFn
from .hashing import uniform01

_INF = jnp.float32(jnp.inf)

# StatFn kind -> seeds-kernel objective code (kernels/seeds.py)
_KERNEL_KIND = {"sum": 0, "count": 1, "thresh": 2, "cap": 3, "moment": 4}


@dataclasses.dataclass(frozen=True)
class MultiSketchSpec:
    """Static half of a MultiSketch (hashable -> usable as jit-static arg).

    Two sketches are mergeable iff their specs are equal: same objectives
    (f, k_f) in the same order, same scheme, same hash seed.
    """

    objectives: Tuple[Tuple[StatFn, int], ...]
    scheme: str = "ppswor"
    seed: int = 0
    capacity: int = 0  # 0 -> default_capacity()

    def __post_init__(self):
        if self.scheme not in ("priority", "ppswor"):
            raise ValueError(
                f"unknown scheme {self.scheme!r} (want 'priority' or 'ppswor')")
        object.__setattr__(self, "objectives",
                           tuple((f, int(k)) for f, k in self.objectives))

    @property
    def nf(self) -> int:
        return len(self.objectives)

    @property
    def kmax(self) -> int:
        return max(k for _, k in self.objectives)

    def default_capacity(self) -> int:
        """sum_f k_f + |F| is a HARD bound on |S^(F) ∪ Z|, so this never
        truncates; the +1 spare slot keeps ``multisketch_overflow`` (slab
        full => possible truncation) False whenever exactness holds."""
        return sum(k for _, k in self.objectives) + self.nf + 1

    @property
    def cap(self) -> int:
        return self.capacity if self.capacity > 0 else self.default_capacity()

    def kernel_objectives(self) -> Optional[Tuple[Tuple[int, float], ...]]:
        """(kind, param) encoding for the fused seeds kernel; None if any
        objective (e.g. combo) has no kernel encoding."""
        enc = []
        for f, _ in self.objectives:
            kind = _KERNEL_KIND.get(f.kind)
            if kind is None:
                return None
            enc.append((kind, float(f.param)))
        return tuple(enc)


class MultiSketch(NamedTuple):
    """Array half of the summary — a plain pytree: jit/donate/collective
    friendly. See module docstring for the wire format."""

    keys: jnp.ndarray     # int32 [c]
    weights: jnp.ndarray  # float32 [c]
    probs: jnp.ndarray    # float32 [c]
    seeds: jnp.ndarray    # float32 [nf, c]
    member: jnp.ndarray   # bool [c]
    aux: jnp.ndarray      # bool [c]
    valid: jnp.ndarray    # bool [c]
    taus: jnp.ndarray     # float32 [nf]


def multisketch_empty(spec: MultiSketchSpec) -> MultiSketch:
    """The identity element of ``merge``/``absorb``."""
    c, nf = spec.cap, spec.nf
    return MultiSketch(
        keys=jnp.full((c,), -1, jnp.int32),
        weights=jnp.zeros((c,), jnp.float32),
        probs=jnp.zeros((c,), jnp.float32),
        seeds=jnp.full((nf, c), _INF, jnp.float32),
        member=jnp.zeros((c,), bool),
        aux=jnp.zeros((c,), bool),
        valid=jnp.zeros((c,), bool),
        taus=jnp.full((nf,), _INF, jnp.float32))


def multisketch_slab_bytes(spec: MultiSketchSpec) -> int:
    """Static wire/device size of ONE slab in bytes — keys/weights/probs
    (3 x 4c) + seeds (4 x nf x c) + member/aux/valid (3 x c) + taus
    (4 x nf). The unit of every bytes-moved model over folds and of the
    engine's ``bytes_resident`` gauge."""
    c, nf = spec.cap, spec.nf
    return c * (15 + 4 * nf) + 4 * nf


# ---------------------------------------------------------------------------
# selection (member/prob/aux/taus over a fixed-shape batch)
# ---------------------------------------------------------------------------

def multisketch_select(spec: MultiSketchSpec, keys, weights, active,
                       use_kernels: bool = False, seed=None):
    """Multi-objective bottom-k selection with the MERGEABLE aux set.

    Returns (member [n], prob [n] = p^(F), aux [n], seeds [nf, n],
    taus [nf]). Differs from core.multi_objective.multi_bottomk_sample only
    in Z: aux holds the threshold key of EVERY objective (merge-sufficient
    superset) instead of the estimation-minimal pruned set; member and prob
    are identical. ``seed`` (runtime override, may be traced) defaults to
    the static spec.seed.
    """
    keys = jnp.asarray(keys, jnp.int32)
    w = jnp.asarray(weights, jnp.float32)
    act = jnp.asarray(active, bool)
    n = keys.shape[0]
    nf = spec.nf
    kks = [min(kf, n) for _, kf in spec.objectives]
    kmax = max(kks)
    seed = spec.seed if seed is None else seed

    enc = spec.kernel_objectives()
    # the seeds kernel bakes the seed in as a compile-time constant; traced
    # seeds (e.g. per-step reseeding inside a jitted exchange) take the
    # XLA path, which accepts them at runtime.
    if use_kernels and enc is not None and isinstance(seed, (int,)):
        from repro.kernels.blockselect import batched_bottomk_select
        from repro.kernels.seeds import fused_seeds_fvals
        seeds, fvals = fused_seeds_fvals(keys, w, act, enc, spec.scheme,
                                         int(seed))
        vals, idx, _ = batched_bottomk_select(seeds, kmax + 1)
    else:
        u = uniform01(keys, seed)
        seeds = jnp.stack([f_seed(w, act, f, u, spec.scheme)
                           for f, _ in spec.objectives])
        fvals = jnp.stack([jnp.where(act, f(w), 0.0)
                           for f, _ in spec.objectives])
        m = min(kmax + 2, n)
        neg, idx = jax.lax.top_k(-seeds, m)     # ONE scan for all objectives
        vals, idx = -neg, idx.astype(jnp.int32)

    # per-objective k-th / (k+1)-th smallest + the threshold key's position
    if vals.shape[1] < kmax + 1:                # n <= kmax: no (k+1)-th seed
        pad = kmax + 1 - vals.shape[1]
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
    rows = jnp.arange(nf)
    kth = vals[rows, jnp.asarray(kks) - 1]                       # [nf]
    taus = vals[rows, jnp.asarray(kks)]                          # [nf]
    thr_idx = idx[rows, jnp.asarray(kks)]                        # [nf]

    member_f = (seeds <= kth[:, None]) & jnp.isfinite(seeds)
    p_f = jnp.where(member_f,
                    conditional_prob(fvals, taus[:, None], spec.scheme), 0.0)
    member = member_f.any(axis=0)
    prob = jnp.where(member, p_f.max(axis=0), 0.0)

    # Z: the (k_f+1)-th smallest-seed key of every objective (if it exists)
    safe = jnp.where(jnp.isfinite(taus) & (thr_idx >= 0), thr_idx, n)
    aux = jnp.zeros((n,), bool).at[safe].set(True, mode="drop") & ~member
    return member, prob, aux, seeds, taus


def _compact(spec: MultiSketchSpec, keys, weights, member, prob, aux, seeds,
             taus, use_kernels: bool) -> MultiSketch:
    """Compact S^(F) ∪ Z into the fixed-capacity slab (members by weight
    desc first, then aux). ``keys`` must be key-sorted if duplicates are
    possible; here they are pre-deduped so order is free."""
    c = spec.cap
    keep = member | aux
    if use_kernels:
        from repro.kernels.compact import compact_take
        take, tvalid = compact_take(keys, weights, member, keep, c)
    else:
        w = jnp.maximum(jnp.asarray(weights, jnp.float32), 0.0)
        inv = 1.0 / (1.0 + w)
        pri = jnp.where(keep & (keys >= 0),
                        jnp.where(member, inv, 2.0 + inv), _INF)
        n = pri.shape[0]
        if n < c:
            pri = jnp.pad(pri, (0, c - n), constant_values=jnp.inf)
        neg, take = jax.lax.top_k(-pri, c)
        tvalid = jnp.isfinite(-neg) & (take < n)
        take = jnp.where(tvalid, take, 0).astype(jnp.int32)
    tk = jnp.where(tvalid, take, 0)
    return MultiSketch(
        keys=jnp.where(tvalid, jnp.asarray(keys, jnp.int32)[tk], -1),
        weights=jnp.where(tvalid, jnp.asarray(weights, jnp.float32)[tk], 0.0),
        probs=jnp.where(tvalid, prob[tk], 0.0),
        seeds=jnp.where(tvalid[None, :], seeds[:, tk], _INF),
        member=member[tk] & tvalid,
        aux=aux[tk] & tvalid,
        valid=tvalid,
        taus=taus)


def _rebuild(spec: MultiSketchSpec, keys, weights, valid,
             use_kernels: bool) -> MultiSketch:
    """Dedup (keep max weight — the paper's w_x for merged data sets),
    re-select, compact. The shared exact-merge core of absorb/merge."""
    keys = jnp.asarray(keys, jnp.int32)
    w = jnp.asarray(weights, jnp.float32)
    # sort (key asc, VALID first, weight desc): each key's first occurrence
    # is its max-weight valid one, so the dup mask can never let an invalid
    # slot shadow a real observation of the same key
    valid = jnp.asarray(valid, bool)
    order = jnp.lexsort((-w, ~valid, keys))
    sk, sw = keys[order], w[order]
    sv = valid[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    act = sv & ~dup & (sk >= 0)
    member, prob, aux, seeds, taus = multisketch_select(
        spec, sk, sw, act, use_kernels=use_kernels)
    return _compact(spec, sk, sw, member, prob, aux, seeds, taus,
                    use_kernels)


# ---------------------------------------------------------------------------
# probs finalizer: one canonical program for the inclusion probability
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("spec",))
def _finalize_probs_jit(weights, seeds, member, valid, taus, *, spec):
    """Recompute p^(F) from the compacted slab in ONE fixed-shape program.

    The retained multiset (keys/weights/seeds/member/taus) of any merge
    path is exact by threshold closure, but ``probs`` passes through a
    transcendental (the ppswor ``-expm1(-f(w)*tau)``), and XLA codegens
    transcendentals with shape-dependent last-ulp rounding — two
    differently-shaped fold programs (a [c] delta fold vs a [m, c]
    stacked re-merge) can disagree by one ulp on the same slab. Host
    entry points therefore overwrite probs with this [c]-shaped program,
    keyed only by spec: identical slabs get identical prob bits no
    matter which fold produced them.

    Per-objective membership is recovered as ``seed < tau`` (strict):
    no seed lies strictly between the k-th smallest (the member bound)
    and tau, the (k+1)-th, so strict-< reproduces the original
    ``seed <= kth`` test exactly (modulo measure-zero seed ties at the
    boundary, impossible for distinct keys under a continuous hash).
    """
    fvals = jnp.stack([jnp.where(valid, f(weights), 0.0)
                       for f, _ in spec.objectives])
    member_f = (seeds < taus[:, None]) & member[None, :]
    p_f = jnp.where(member_f,
                    conditional_prob(fvals, taus[:, None], spec.scheme), 0.0)
    return jnp.where(member, p_f.max(axis=0), 0.0)


def multisketch_finalize(sk: MultiSketch, *,
                         spec: MultiSketchSpec) -> MultiSketch:
    """Canonicalize ``sk.probs`` (see ``_finalize_probs_jit``). Idempotent;
    every host-level producer in this module applies it on return, so
    slabs with equal retained state compare bit-equal in all 8 fields."""
    return sk._replace(probs=_finalize_probs_jit(
        sk.weights, sk.seeds, sk.member, sk.valid, sk.taus, spec=spec))


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _build_body(keys, weights, active, spec, use_kernels, seed=None):
    n = keys.shape[0]
    npad = max(n, spec.kmax + 2)  # selection needs a (kmax+1)-th candidate
    if npad > n:
        keys = jnp.pad(keys, (0, npad - n), constant_values=-1)
        weights = jnp.pad(weights, (0, npad - n))
        active = jnp.pad(active, (0, npad - n))
    member, prob, aux, seeds, taus = multisketch_select(
        spec, keys, weights, active, use_kernels=use_kernels, seed=seed)
    return _compact(spec, keys, weights, member, prob, aux, seeds, taus,
                    use_kernels)


@partial(jax.jit, static_argnames=("spec", "use_kernels"))
def _build_jit(keys, weights, active, *, spec, use_kernels):
    return _build_body(keys, weights, active, spec, use_kernels)


@partial(jax.jit, static_argnames=("spec", "use_kernels"))
def _build_seeded_jit(keys, weights, active, seed, *, spec, use_kernels):
    return _build_body(keys, weights, active, spec, use_kernels, seed=seed)


def multisketch_build(spec: MultiSketchSpec, keys, weights, active=None,
                      use_kernels: Optional[bool] = None,
                      seed=None) -> MultiSketch:
    """One-shot S^(F) ∪ Z over a batch, compacted to the wire format.

    Assumes distinct keys (as the paper's data model does); duplicate keys
    in ONE batch are sampled as distinct observations — route repeated keys
    through ``absorb``/``merge``, which dedup by max weight.

    ``seed``: optional RUNTIME hash-seed override (a traced int32 is fine)
    — many-seed callers (replication studies, the metric-domain sampler)
    share ONE compiled executable instead of retracing per spec.seed. The
    seeded path always uses the XLA selection (the kernels bake the seed
    in at compile time).
    """
    keys = jnp.asarray(keys, jnp.int32)
    weights = jnp.asarray(weights, jnp.float32)
    active = (jnp.ones(keys.shape, bool) if active is None
              else jnp.asarray(active, bool))
    if seed is not None:
        return multisketch_finalize(
            _build_seeded_jit(keys, weights, active,
                              jnp.asarray(seed, jnp.int32),
                              spec=spec, use_kernels=False), spec=spec)
    return multisketch_finalize(
        _build_jit(keys, weights, active, spec=spec,
                   use_kernels=True if use_kernels is None else use_kernels),
        spec=spec)


def multisketch_absorb_inline(spec: MultiSketchSpec, state: MultiSketch,
                              keys, weights, active=None,
                              use_kernels: bool = False) -> MultiSketch:
    """Pure (un-jitted) fold body: state <- state ∪ chunk.

    For callers that are ALREADY inside a jit trace (a train step folding
    telemetry, a shard_map exchange) — fuses into the enclosing program.
    Host callers want :func:`multisketch_absorb` (jitted, donated buffers).
    """
    keys = jnp.asarray(keys, jnp.int32).reshape(-1)
    weights = jnp.asarray(weights, jnp.float32).reshape(-1)
    active = (jnp.ones(keys.shape, bool) if active is None
              else jnp.asarray(active, bool).reshape(-1))
    ck = jnp.concatenate([state.keys, keys])
    cw = jnp.concatenate([state.weights, weights])
    cv = jnp.concatenate([state.valid, active])
    return _rebuild(spec, ck, cw, cv, use_kernels)


@partial(jax.jit, static_argnames=("spec", "use_kernels"),
         donate_argnums=(0,))
def _absorb_jit(state, keys, weights, active, *, spec, use_kernels):
    return multisketch_absorb_inline(spec, state, keys, weights, active,
                                     use_kernels)


def multisketch_absorb(state: MultiSketch, keys, weights, active=None, *,
                       spec: MultiSketchSpec,
                       use_kernels: Optional[bool] = None) -> MultiSketch:
    """Device-resident streaming fold: state <- state ∪ chunk.

    jit-compiled per (spec, chunk shape) with the STATE BUFFERS DONATED —
    the returned sketch reuses the old state's memory, so a training loop
    folds telemetry with zero host round-trips and zero steady-state
    allocation. The old ``state`` must not be used again.
    """
    keys = jnp.asarray(keys, jnp.int32).reshape(-1)
    return multisketch_finalize(_absorb_jit(
        state, keys, jnp.asarray(weights, jnp.float32).reshape(-1),
        (jnp.ones(keys.shape, bool) if active is None
         else jnp.asarray(active, bool).reshape(-1)),
        spec=spec, use_kernels=True if use_kernels is None else use_kernels),
        spec=spec)


@partial(jax.jit, static_argnames=("spec", "use_kernels"),
         donate_argnums=(0, 1, 2, 3, 4, 5, 6, 7))
def _absorb_into_jit(skeys, sweights, sprobs, sseeds, smember, saux, svalid,
                     staus, dkeys, dweights, dvalid, *, spec, use_kernels):
    """The delta fold body: flat state leaves (all donated — the incremental
    merge reuses the cached merged slab's buffers) + the delta's
    keys/weights/valid only (seeds/probs are recomputed by re-selection, so
    the delta slabs' other leaves never leave the device's resident state)."""
    del sprobs, sseeds, smember, saux, staus  # donated, recomputed
    return _rebuild(spec,
                    jnp.concatenate([skeys, dkeys]),
                    jnp.concatenate([sweights, dweights]),
                    jnp.concatenate([svalid, dvalid]), use_kernels)


def delta_slab_pad(keys, weights, valid, cap: int, m_quantum: int = 1):
    """Pad a flattened delta (m slabs x cap slots) with inert slots (key -1,
    weight 0, invalid) so the slab count reaches the next power-of-two
    multiple of ``m_quantum`` — incremental merges with 1, 2, 3.. dirty
    shards then share O(log m) compiled executables instead of one per m."""
    m = -(-keys.shape[0] // cap)
    mq = max(m_quantum, 1)
    while mq < m:
        mq *= 2
    pad = mq * cap - keys.shape[0]
    if pad:
        keys = jnp.concatenate([keys, jnp.full((pad,), -1, jnp.int32)])
        weights = jnp.concatenate([weights, jnp.zeros((pad,), jnp.float32)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    return keys, weights, valid


def multisketch_absorb_into(state: MultiSketch, delta: MultiSketch, *,
                            spec: MultiSketchSpec,
                            use_kernels: Optional[bool] = None,
                            pad_deltas: bool = True) -> MultiSketch:
    """Delta-aware incremental merge: state <- state ∪ delta, IN PLACE.

    ``state`` is an already-merged slab (e.g. a query engine's cached
    merged slab) whose buffers are DONATED — the result reuses its memory,
    and the old handle must not be used again. ``delta`` is one sketch or a
    stacked batch (leaves [m, c]) of sketches under the same spec — the
    dirty shards of an absorb epoch; its buffers are NOT donated (shard
    slabs stay resident).

    Exactness (core.merge docstring): ``state`` summarizes the union data
    set U and each delta slab summarizes some D_i, so re-selection over the
    concatenated retained keys reproduces the sketch of U ∪ (∪ D_i) —
    bit-identical to a full re-merge over ALL shards whenever U covers
    every non-dirty shard's data, i.e. after any sequence of absorbs
    (monotone additions). Replacing a shard's content wholesale
    (``set_shard``/``load_stacked``) voids that containment; callers must
    take the full-merge path there.

    ``use_kernels=None`` resolves to the backend default — the fused
    kernel chain on a real accelerator, the bit-compatible XLA selection
    when kernels would run under the Pallas interpreter (slab-scale
    rebuilds are latency-bound; the interpreted chain is ~15x slower than
    its XLA twin while producing identical bits).
    """
    return multisketch_absorb_slabs(state, delta.keys, delta.weights,
                                    delta.valid, spec=spec,
                                    use_kernels=use_kernels,
                                    pad_deltas=pad_deltas)


def multisketch_absorb_slabs(state: MultiSketch, delta_keys, delta_weights,
                             delta_valid, *, spec: MultiSketchSpec,
                             use_kernels: Optional[bool] = None,
                             pad_deltas: bool = True) -> MultiSketch:
    """`multisketch_absorb_into` taking the delta's three CONSUMED leaves
    directly ([c] or [m, c]) — re-selection recomputes probs/seeds/taus,
    so callers holding whole sketches (the engine's dirty shards) need
    not stack the other five leaves just to have them discarded."""
    if use_kernels is None:
        from repro.kernels._util import default_interpret
        use_kernels = not default_interpret()
    # the hot path (one dirty shard, resident slab leaves) must not pay
    # per-op dispatch: reshape/convert only when the delta is stacked or
    # host-side, and padding is a no-op at an exact power-of-two count
    dk, dw, dv = delta_keys, delta_weights, delta_valid
    if getattr(dk, "ndim", None) != 1:
        dk = jnp.asarray(dk, jnp.int32).reshape(-1)
        dw = jnp.asarray(dw, jnp.float32).reshape(-1)
        dv = jnp.asarray(dv, bool).reshape(-1)
    if pad_deltas and dk.shape[0] != spec.cap:
        dk, dw, dv = delta_slab_pad(dk, dw, dv, spec.cap)
    return multisketch_finalize(
        _absorb_into_jit(state.keys, state.weights, state.probs,
                         state.seeds, state.member, state.aux,
                         state.valid, state.taus, dk, dw, dv,
                         spec=spec, use_kernels=use_kernels), spec=spec)


@partial(jax.jit, static_argnames=("spec", "use_kernels"))
def _merge_jit(a, b, *, spec, use_kernels):
    return _rebuild(spec,
                    jnp.concatenate([a.keys, b.keys]),
                    jnp.concatenate([a.weights, b.weights]),
                    jnp.concatenate([a.valid, b.valid]), use_kernels)


def multisketch_merge(spec: MultiSketchSpec, a: MultiSketch, b: MultiSketch,
                      use_kernels: Optional[bool] = None) -> MultiSketch:
    """Exact merge of two sketches built under the same spec."""
    return multisketch_finalize(_merge_jit(
        a, b, spec=spec,
        use_kernels=True if use_kernels is None else use_kernels), spec=spec)


def multisketch_merge_stacked(spec: MultiSketchSpec, stacked: MultiSketch,
                              use_kernels: bool = False) -> MultiSketch:
    """Merge a stacked batch of sketches (leaves have a leading [m] axis,
    e.g. straight out of ``all_gather``) in ONE re-selection — no tree
    reduction. Works inside shard_map (default use_kernels=False; the
    finalize inlines into the enclosing trace there — in-trace callers
    that need canonical prob bits re-finalize the host-level result, as
    ``launch.summary.sharded_multisketch`` does)."""
    return multisketch_finalize(
        _rebuild(spec, stacked.keys.reshape(-1),
                 stacked.weights.reshape(-1), stacked.valid.reshape(-1),
                 use_kernels), spec=spec)


def pad_chunk(keys, weights, active=None, chunk: int = 256):
    """Pad a host chunk of keyed observations to the ``chunk`` quantum
    (keys -1, weights 0, inactive) so the absorb fold's jit traces stay
    bounded. ``active`` defaults to weights > 0. Shared by every host
    collector fronting :func:`multisketch_absorb`."""
    import numpy as np
    keys = np.asarray(keys, np.int32).reshape(-1)
    weights = np.asarray(weights, np.float32).reshape(-1)
    active = (weights > 0 if active is None
              else np.asarray(active, bool).reshape(-1))
    n = keys.shape[0]
    npad = max(chunk, -(-n // chunk) * chunk)
    if npad > n:
        keys = np.pad(keys, (0, npad - n), constant_values=-1)
        weights = np.pad(weights, (0, npad - n))
        active = np.pad(active, (0, npad - n))
    return keys, weights, active


def quarantine_chunk(keys, weights, active=None):
    """Per-ROW input quarantine for absorb paths facing untrusted producers.

    A malformed row — NaN/inf/negative weight, NaN/inf/negative or
    out-of-int32-range key — is rejected individually (marked inactive,
    weight zeroed, key set to -1) instead of poisoning or dropping the
    whole chunk: the surviving rows fold exactly as if the producer had
    never sent the bad ones (an inactive slot is indistinguishable from
    ``pad_chunk`` padding, so the resulting slab is bit-identical to
    absorbing only the clean rows at the same chunk quantum).

    Returns ``(keys int32, weights float32, active bool, n_quarantined)``
    where ``n_quarantined`` counts rows that were active on entry but
    rejected here — the per-stream poison-producer health signal
    (``EnginePool`` accumulates it per tenant).
    """
    import numpy as np
    kf = np.asarray(keys).reshape(-1).astype(np.float64)
    wf = np.asarray(weights).reshape(-1).astype(np.float64)
    act = (np.ones(kf.shape, bool) if active is None
           else np.asarray(active, bool).reshape(-1))
    bad_w = ~np.isfinite(wf) | (wf < 0.0)
    bad_k = (~np.isfinite(kf) | (kf < 0.0)
             | (kf > float(np.iinfo(np.int32).max)))
    bad = bad_w | bad_k
    n_quarantined = int(np.count_nonzero(bad & act))
    out_k = np.where(bad, -1.0, kf).astype(np.int32)
    out_w = np.where(bad, 0.0, wf).astype(np.float32)
    return out_k, out_w, act & ~bad, n_quarantined


def statfn_to_meta(f: StatFn) -> dict:
    """JSON-able encoding of a StatFn (combo recurses)."""
    d = {"kind": f.kind, "param": float(f.param)}
    if f.kind == "combo":
        d["terms"] = [[float(c), statfn_to_meta(g)] for c, g in f.terms]
    return d


def statfn_from_meta(d: dict) -> StatFn:
    terms = tuple((float(c), statfn_from_meta(g))
                  for c, g in d.get("terms", []))
    return StatFn(d["kind"], float(d.get("param", 0.0)), terms)


def spec_to_meta(spec: MultiSketchSpec) -> dict:
    """JSON-able encoding of a spec — the static half of the checkpoint
    wire format (ckpt.manager stores it beside the slab arrays, so a
    restoring job reconstructs the spec without sharing code state)."""
    return {"objectives": [[statfn_to_meta(f), int(k)]
                           for f, k in spec.objectives],
            "scheme": spec.scheme, "seed": int(spec.seed),
            "capacity": int(spec.capacity)}


def spec_from_meta(d: dict) -> MultiSketchSpec:
    return MultiSketchSpec(
        objectives=tuple((statfn_from_meta(f), int(k))
                         for f, k in d["objectives"]),
        scheme=d["scheme"], seed=int(d["seed"]),
        capacity=int(d.get("capacity", 0)))


def multisketch_overflow(sk: MultiSketch) -> jnp.ndarray:
    """True iff the slab is full — i.e. compaction MAY have truncated
    S ∪ Z and the exact-merge guarantee is voided. Never True at the
    default capacity (one spare slot past the hard |S ∪ Z| bound)."""
    return jnp.all(sk.valid)


def multisketch_estimate(sk: MultiSketch, f: StatFn,
                         segment_fn=None) -> jnp.ndarray:
    """HT estimate of Q(f, H) from the sketch (paper Eq. 5: inverse
    p^(F) weighting). ``segment_fn``: vectorized key predicate for H."""
    from .merge import sketch_estimate
    return sketch_estimate(sk, f, segment_fn)


@partial(jax.jit, static_argnames=("fs", "use_kernels"))
def _estimate_batch_jit(keys, weights, probs, member, table, *, fs,
                        use_kernels):
    if use_kernels:
        from repro.kernels.segquery import segment_query_slab
        enc = tuple((_KERNEL_KIND[f.kind], float(f.param)) for f in fs)
        return segment_query_slab(keys, weights, probs, member, table, enc)
    from .estimators import estimate_many
    from .predicates import predicate_matrix
    return estimate_many(fs, weights, probs, member,
                         predicate_matrix(keys, table))


def multisketch_query_many(sk: MultiSketch, fs, predicates,
                           b_quantum: int = 16,
                           use_kernels: Optional[bool] = None):
    """Host-facing batched query: encode predicates, pad B up to a
    ``b_quantum`` bucket (with never-matching rows, so same-bucket batches
    share one compiled executable), run the fused estimate, slice back.
    Returns float numpy [|F|, B].

    B == 1 skips the bucketing and runs the one-row table directly — a
    single query is its own jit-cache bucket (one fixed shape, so traces
    stay bounded) and must not pay a ``b_quantum``-wide estimate; this is
    the single-query fast path every engine ``query`` routes through."""
    import numpy as np

    from .predicates import encode_predicates, pad_table
    table = encode_predicates(predicates)
    b = table.shape[0]
    # exactly B == 1: an empty (B=0) table still takes the bucketed path,
    # which degrades to a padded all-never batch and an empty [:, :0] slice
    bpad = 1 if b == 1 else max(b_quantum, -(-b // b_quantum) * b_quantum)
    out = multisketch_estimate_batch(sk, tuple(fs), pad_table(table, bpad),
                                     use_kernels=use_kernels)
    return np.asarray(out)[:, :b]


def multisketch_estimate_batch(sk: MultiSketch, fs, predicates,
                               use_kernels: Optional[bool] = None
                               ) -> jnp.ndarray:
    """Batched HT estimates Q(f_i, H_b) -> [|F|, B] from ONE slab pass.

    fs: sequence of StatFn; predicates: SegmentPredicate(s) or an encoded
    int32 wire table [B, PRED_COLS] (core.predicates). The kernel path
    (default when every f has a seeds-kernel encoding) is a single Pallas
    launch for the whole B x |F| batch; combo objectives or
    use_kernels=False take the bit-compatible XLA path (one contribution
    matrix + one matmul).
    """
    from .predicates import encode_predicates
    fs = tuple(fs)
    table = jnp.asarray(encode_predicates(predicates), jnp.int32)
    uk = True if use_kernels is None else use_kernels
    uk = uk and all(f.kind in _KERNEL_KIND for f in fs)
    return _estimate_batch_jit(sk.keys, sk.weights, sk.probs, sk.member,
                               table, fs=fs, use_kernels=uk)
