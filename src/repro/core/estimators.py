"""Inverse-probability (Horvitz–Thompson) estimators for segment f-statistics.

Q^(g, H) = sum_{x in S ∩ H} g(w_x) / p_x     (paper Eq. 2 / Eq. 5)

Unbiased whenever g(w) > 0 => p > 0; nonnegative always. CV guarantees:
Thm 2.1 (single objective), Thm 3.1 (multi-objective), §5.1 (universal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .funcs import StatFn


def estimate(f: StatFn, weights, probs, member, segment=None):
    """Q^(f, H). ``segment``: bool mask for H (None = whole key space)."""
    sel = member if segment is None else (member & segment)
    contrib = jnp.where(sel, f(weights) / jnp.maximum(probs, 1e-30), 0.0)
    return jnp.sum(contrib)


def estimate_segments(f: StatFn, weights, probs, member, segment_ids,
                      num_segments: int):
    """Q^(f, H_j) for a partition into ``num_segments`` segments at once."""
    contrib = jnp.where(member, f(weights) / jnp.maximum(probs, 1e-30), 0.0)
    return jax.ops.segment_sum(contrib, segment_ids,
                               num_segments=num_segments)


def estimate_many(fs, weights, probs, member, segments):
    """Q^(f_i, H_b) for |F| objectives x B (possibly overlapping) segments.

    fs: sequence of StatFn; segments: bool [B, n] (one mask row per segment,
    unlike ``estimate_segments``'s disjoint partition). Returns [|F|, B].
    One |F| x n contribution matrix and one matmul against the segment mask
    — the XLA mirror of the single-launch segquery kernel.
    """
    probs = jnp.asarray(probs, jnp.float32)
    ht = jnp.where(member, 1.0 / jnp.maximum(probs, 1e-30), 0.0)
    contrib = jnp.stack([f(weights) for f in fs]) * ht          # [F, n]
    return contrib @ jnp.asarray(segments).astype(jnp.float32).T


def exact(f: StatFn, weights, active, segment=None):
    """Ground-truth Q(f, H) for validation."""
    sel = active if segment is None else (active & segment)
    return jnp.sum(jnp.where(sel, f(weights), 0.0))


def exact_segments(f: StatFn, weights, active, segment_ids, num_segments: int):
    contrib = jnp.where(active, f(weights), 0.0)
    return jax.ops.segment_sum(contrib, segment_ids,
                               num_segments=num_segments)


def cv_bound(q_rel: float, k: int, rho: float = 1.0) -> float:
    """Paper CV upper bound sqrt(rho / (q * (k-1))) (bottom-k variant)."""
    return float(jnp.sqrt(rho / (max(q_rel, 1e-30) * max(k - 1, 1))))
