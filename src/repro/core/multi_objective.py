"""Multi-objective samples S^(F) (paper §3).

PPS (§3.1):     p_x^(F) = max_{(f,k_f) in F} p_x^(f,k_f)            (Eq. 4)
Bottom-k (§3.2): S^(F) = U_f S^(f,k_f) under SHARED u_x; estimation uses the
conditional inclusion probability p_x^(F) = max_f p_x^(f), with the auxiliary
key set Z retained so the probabilities are computable from the sample alone.

Estimates from S^(F) dominate every dedicated sample simultaneously
(Thm 3.1): CV[Q^(g,H)] <= min_f sqrt(rho(f,g) / (q^(g)(H) k_f)).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from .bottomk import conditional_prob, f_seed
from .funcs import StatFn
from .hashing import uniform01
from .pps import pps_probabilities


class MultiPps(NamedTuple):
    member: jnp.ndarray  # bool [n]
    prob: jnp.ndarray    # float32 [n] — p_x^(F)
    fsums: jnp.ndarray   # float32 [|F|] — auxiliary per-objective totals


def multi_pps_sample(keys, weights, active, objectives: Sequence[Tuple[StatFn, int]],
                     seed=0) -> MultiPps:
    """Multi-objective pps sample (Eq. 4), coordinated via shared u_x."""
    probs = []
    fsums = []
    for f, kf in objectives:
        p, s = pps_probabilities(weights, active, f, kf)
        probs.append(p)
        fsums.append(s)
    p_F = jnp.stack(probs).max(axis=0)
    u = uniform01(keys, seed)
    return MultiPps(member=(u < p_F), prob=p_F, fsums=jnp.stack(fsums))


class MultiBottomK(NamedTuple):
    member: jnp.ndarray   # bool [n] — x in S^(F) = union of dedicated samples
    prob: jnp.ndarray     # float32 [n] — p_x^(F) = max_f p_x^(f) for members
    aux: jnp.ndarray      # bool [n] — x in Z (auxiliary; carries (u_x, w_x))
    taus: jnp.ndarray     # float32 [|F|] — tau^(f,k_f) per objective


def multi_bottomk_sample(keys, weights, active,
                         objectives: Sequence[Tuple[StatFn, int]],
                         scheme: str = "ppswor", seed=0) -> MultiBottomK:
    """Multi-objective bottom-k sample S^(F) with aux keys Z (paper §3.2).

    All per-objective samples share the same u_x (coordination). For each
    objective (f, k_f):
      member_f(x): f-seed(x) among k_f smallest
      tau_f = (k_f+1)-th smallest f-seed  (threshold key = the arg of tau_f)
    Z collects, for each member x, the threshold key y_x of its most forgiving
    objective g_x — keys that are needed to recompute p_x^(F) from the sample
    but are not themselves members.
    """
    u = uniform01(keys, seed)
    n = weights.shape[0]
    nf = len(objectives)

    # Seeds and f-values for every objective under the SAME u_x, stacked
    # [|F|, n]; thresholds for ALL objectives come from ONE batched
    # top_k(max_k + 1) scan instead of 2 full-n scans per objective.
    seeds_F = jnp.stack([f_seed(weights, active, f, u, scheme)
                         for f, _ in objectives])
    fv_F = jnp.stack([jnp.where(active, f(weights), 0.0)
                      for f, _ in objectives])
    kks = [min(kf, n) for _, kf in objectives]
    sorted_vals = -jax.lax.top_k(-seeds_F, min(max(kks) + 1, n))[0]
    kth = jnp.stack([sorted_vals[j, kk - 1] for j, kk in enumerate(kks)])
    taus = jnp.stack([sorted_vals[j, kk] if n > kk else jnp.float32(jnp.inf)
                      for j, kk in enumerate(kks)])

    members_F = ((seeds_F < kth[:, None])
                 | ((seeds_F == kth[:, None]) & jnp.isfinite(seeds_F)))
    probs = jnp.where(members_F,
                      conditional_prob(fv_F, taus[:, None], scheme), 0.0)
    # threshold key of objective f: the key whose seed == tau_f
    thr_key_onehots = jnp.isfinite(taus)[:, None] & (seeds_F == taus[:, None])

    member = members_F.any(axis=0)
    p_F = probs.max(axis=0)
    # g_x = argmax_f p_x^(f) among objectives with x in S^(f) — since p_f is 0
    # for non-members of f, the plain argmax implements the paper's g_x.
    g_x = probs.argmax(axis=0)          # [n]
    # Z = {y_x : x in S^(F), p_x^(g_x) < 1} \ S^(F): union of threshold keys of
    # objectives that are "g_x" for at least one member with p < 1.
    member_needs = member & (p_F < 1.0)
    needed_f = jnp.any(member_needs[None, :]
                       & (g_x[None, :] == jnp.arange(nf)[:, None]), axis=1)
    aux = jnp.any(thr_key_onehots & needed_f[:, None], axis=0) & ~member
    return MultiBottomK(member=member, prob=jnp.where(member, p_F, 0.0),
                        aux=aux, taus=taus)
