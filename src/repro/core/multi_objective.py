"""Multi-objective samples S^(F) (paper §3).

PPS (§3.1):     p_x^(F) = max_{(f,k_f) in F} p_x^(f,k_f)            (Eq. 4)
Bottom-k (§3.2): S^(F) = U_f S^(f,k_f) under SHARED u_x; estimation uses the
conditional inclusion probability p_x^(F) = max_f p_x^(f), with the auxiliary
key set Z retained so the probabilities are computable from the sample alone.

Estimates from S^(F) dominate every dedicated sample simultaneously
(Thm 3.1): CV[Q^(g,H)] <= min_f sqrt(rho(f,g) / (q^(g)(H) k_f)).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax.numpy as jnp

from .bottomk import _kth_smallest, conditional_prob, f_seed
from .funcs import StatFn
from .hashing import uniform01
from .pps import pps_probabilities


class MultiPps(NamedTuple):
    member: jnp.ndarray  # bool [n]
    prob: jnp.ndarray    # float32 [n] — p_x^(F)
    fsums: jnp.ndarray   # float32 [|F|] — auxiliary per-objective totals


def multi_pps_sample(keys, weights, active, objectives: Sequence[Tuple[StatFn, int]],
                     seed=0) -> MultiPps:
    """Multi-objective pps sample (Eq. 4), coordinated via shared u_x."""
    probs = []
    fsums = []
    for f, kf in objectives:
        p, s = pps_probabilities(weights, active, f, kf)
        probs.append(p)
        fsums.append(s)
    p_F = jnp.stack(probs).max(axis=0)
    u = uniform01(keys, seed)
    return MultiPps(member=(u < p_F), prob=p_F, fsums=jnp.stack(fsums))


class MultiBottomK(NamedTuple):
    member: jnp.ndarray   # bool [n] — x in S^(F) = union of dedicated samples
    prob: jnp.ndarray     # float32 [n] — p_x^(F) = max_f p_x^(f) for members
    aux: jnp.ndarray      # bool [n] — x in Z (auxiliary; carries (u_x, w_x))
    taus: jnp.ndarray     # float32 [|F|] — tau^(f,k_f) per objective


def multi_bottomk_sample(keys, weights, active,
                         objectives: Sequence[Tuple[StatFn, int]],
                         scheme: str = "ppswor", seed=0) -> MultiBottomK:
    """Multi-objective bottom-k sample S^(F) with aux keys Z (paper §3.2).

    All per-objective samples share the same u_x (coordination). For each
    objective (f, k_f):
      member_f(x): f-seed(x) among k_f smallest
      tau_f = (k_f+1)-th smallest f-seed  (threshold key = the arg of tau_f)
    Z collects, for each member x, the threshold key y_x of its most forgiving
    objective g_x — keys that are needed to recompute p_x^(F) from the sample
    but are not themselves members.
    """
    u = uniform01(keys, seed)
    n = weights.shape[0]

    member = jnp.zeros((n,), bool)
    probs = []
    taus = []
    thr_key_onehots = []  # one-hot of the threshold key per objective
    members_f = []
    for f, kf in objectives:
        seeds = f_seed(weights, active, f, u, scheme)
        kk = min(kf, n)
        kth = _kth_smallest(seeds, kk)
        m_f = (seeds < kth) | ((seeds == kth) & jnp.isfinite(seeds))
        tau_f = _kth_smallest(seeds, kk + 1) if n > kk else jnp.float32(jnp.inf)
        fv = jnp.where(active, f(weights), 0.0)
        p_f = jnp.where(m_f, conditional_prob(fv, tau_f, scheme), 0.0)
        member = member | m_f
        probs.append(p_f)
        taus.append(tau_f)
        members_f.append(m_f)
        # threshold key of objective f: the key whose seed == tau_f
        thr_key_onehots.append(jnp.isfinite(tau_f) & (seeds == tau_f))

    probs = jnp.stack(probs)            # [|F|, n]
    p_F = probs.max(axis=0)
    # g_x = argmax_f p_x^(f) among objectives with x in S^(f) — since p_f is 0
    # for non-members of f, the plain argmax implements the paper's g_x.
    g_x = probs.argmax(axis=0)          # [n]
    # Z = {y_x : x in S^(F), p_x^(g_x) < 1} \ S^(F): union of threshold keys of
    # objectives that are "g_x" for at least one member with p < 1.
    needed_f = jnp.zeros((len(objectives),), bool)
    member_needs = member & (p_F < 1.0)
    for i in range(len(objectives)):
        needed_f = needed_f.at[i].set(jnp.any(member_needs & (g_x == i)))
    aux = jnp.zeros((n,), bool)
    for i, oh in enumerate(thr_key_onehots):
        aux = aux | (oh & needed_f[i])
    aux = aux & ~member
    return MultiBottomK(member=member, prob=jnp.where(member, p_F, 0.0),
                        aux=aux, taus=jnp.stack(taus))
