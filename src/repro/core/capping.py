"""Universal capping sample S^(C,k), C = {cap_T : T > 0} (paper §6).

Membership (Lemma 6.3):  x in S^(C,k)  <=>  h_x + l_x < k, where
    h_x = #{y : w_y >= w_x and u_y < u_x}                (same h as §5)
    l_x = #{y : w_y <  w_x and r_y / w_y < r_x / w_x}    (ppswor ranks r)

Estimation (Cor. 6.2 + Eq. 3): p_x = Pr_{u_x}[ r_x / w_x < t_x ] where t_x is
the k-th smallest cap_{w_x}-seed among keys y != x, and
cap_{w_x}-seed(y) = r_y / min(w_y, w_x). The k+1 smallest cap_{w_x}-seeds all
belong to keys with h_y + l_y <= k (Lemma 6.1/6.4 argument: a key's seed rank
is minimized at T = w_y), so the final pass may be restricted to the small
candidate set {h + l <= k} — this is the paper's §6.1 algorithm.

Size (Thm 6.1): E|S^(C,k)| <= e k ln(w_max/w_min) — verified in benchmarks.

Production path = two sort+buffer scans (h-scan by (-w, u); l-scan by (w, rw))
+ an O(m^2) pairwise pass on the m candidate keys (m is a static capacity;
expected candidates ~ k ln(w_max/w_min) << n).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bottomk import conditional_prob
from .hashing import rank_of, uniform01
from .universal import _buffer_scan, _INF


class CappingSample(NamedTuple):
    member: jnp.ndarray  # bool [n]
    prob: jnp.ndarray    # float32 [n] — p_x^(C,k) for members else 0
    aux: jnp.ndarray     # bool [n] — potential/actual auxiliary keys (h+l == k)
    hl: jnp.ndarray      # int32 [n] — h_x + l_x capped at k+1


def _pairwise_capping(w, r, act, k: int):
    """t_x = k-th smallest cap_{w_x}-seed over y != x. O(n^2). w,r: [n]."""
    n = w.shape[0]
    capw = jnp.minimum(w[None, :], w[:, None])            # cap_{w_x}(w_y), [x,y]
    seeds = jnp.where(act[None, :] & (capw > 0), r[None, :] / jnp.maximum(capw, 1e-30), _INF)
    seeds = jnp.where(jnp.eye(n, dtype=bool), _INF, seeds)  # exclude y == x
    srt = jnp.sort(seeds, axis=1)
    t = srt[:, k - 1] if n >= k else jnp.full((n,), _INF)
    return t


def universal_capping_ref(weights, u, active, k: int,
                          scheme: str = "ppswor") -> CappingSample:
    """Exact O(n^2) oracle."""
    w = jnp.asarray(weights, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    act = jnp.asarray(active, bool) & (w > 0)
    r = rank_of(u, scheme)
    rw = jnp.where(act, r / jnp.maximum(w, 1e-30), _INF)

    h = jnp.sum((act[None, :] & (w[None, :] >= w[:, None])
                 & (u[None, :] < u[:, None])), axis=1)
    l = jnp.sum((act[None, :] & (w[None, :] < w[:, None])
                 & (rw[None, :] < rw[:, None])), axis=1)
    hl = (h + l).astype(jnp.int32)
    member = act & (hl < k)
    aux = act & (hl == k)

    t = _pairwise_capping(w, r, act, k)
    p = jnp.where(member, conditional_prob(w, t, scheme), 0.0)
    return CappingSample(member=member, prob=p, aux=aux,
                         hl=jnp.minimum(hl, k + 1))


def universal_capping_sample(keys, weights, active, k: int, m_cap: int,
                             scheme: str = "ppswor", seed=0,
                             u=None) -> CappingSample:
    """Production S^(C,k): two buffer scans + O(m_cap^2) candidate pass.

    m_cap: static capacity for the candidate set {h + l <= k}. If the true
    candidate count exceeds m_cap (raise it ~ e*k*ln(w_max/w_min) + slack),
    excess candidates are dropped from the pairwise pass; membership bits
    remain exact (they come from the scans), only probs of dropped members
    would be wrong — we detect overflow and report it via ``hl`` sentinel.
    """
    w = jnp.asarray(weights, jnp.float32)
    act = jnp.asarray(active, bool) & (w > 0)
    if u is None:
        u = uniform01(keys, seed)
    u = jnp.asarray(u, jnp.float32)
    r = rank_of(u, scheme)
    n = w.shape[0]
    pos = jnp.arange(n)

    # --- h-scan: process by decreasing w (ties: increasing u) ---------------
    order_h = jnp.lexsort((u, -jnp.where(act, w, -_INF)))
    rank_h, _, _ = _buffer_scan(jnp.where(act[order_h], u[order_h], _INF),
                                pos[order_h], k + 1)
    h = jnp.zeros((n,), jnp.int32).at[order_h].set(
        jnp.minimum(rank_h, k + 1).astype(jnp.int32))

    # --- l-scan: process by increasing w (ties: increasing r/w) -------------
    rw = jnp.where(act, r / jnp.maximum(w, 1e-30), _INF)
    order_l = jnp.lexsort((rw, jnp.where(act, w, _INF)))
    sw = jnp.where(act, w, _INF)[order_l]
    rank_l, _, _ = _buffer_scan(jnp.where(act[order_l], rw[order_l], _INF),
                                pos[order_l], k + 1)
    # subtract within-weight-group position: same-weight earlier keys all have
    # smaller r/w and were counted by the scan but are NOT in {w_y < w_x}.
    is_start = jnp.concatenate([jnp.ones((1,), bool), sw[1:] != sw[:-1]])
    gstart = jax.lax.cummax(jnp.where(is_start, jnp.arange(n), 0), axis=0)
    gpos = jnp.arange(n) - gstart
    sat = rank_l >= k + 1  # saturated => h+l > k regardless (see module doc)
    l_sorted = jnp.where(sat, k + 1, jnp.maximum(rank_l - gpos, 0))
    l = jnp.zeros((n,), jnp.int32).at[order_l].set(l_sorted.astype(jnp.int32))

    hl = jnp.minimum(h + l, k + 1)
    member = act & (hl < k)
    aux = act & (hl == k)

    # --- candidate pass: exact t_x over the {h+l <= k} set ------------------
    cand_mask = act & (hl <= k)
    cand_idx = jnp.where(cand_mask, pos, n)
    cand_idx = jnp.sort(cand_idx)[:m_cap]          # first m_cap candidates
    valid = cand_idx < n
    ci = jnp.where(valid, cand_idx, 0)
    cw, cr, cact = w[ci], r[ci], valid & act[ci]
    t_c = _pairwise_capping(cw, cr, cact, k)
    p_c = conditional_prob(cw, t_c, scheme)
    prob = jnp.zeros((n,), jnp.float32).at[jnp.where(valid, ci, n)].set(
        p_c, mode="drop")
    prob = jnp.where(member, prob, 0.0)
    return CappingSample(member=member, prob=prob, aux=aux, hl=hl)


def capping_size_bound(k: int, w_max: float, w_min: float) -> float:
    """Thm 6.1: E|S^(C,k)| <= e k ln(w_max / w_min)."""
    import math
    return math.e * k * max(1.0, math.log(max(w_max / max(w_min, 1e-30), math.e)))
