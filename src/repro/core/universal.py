"""Universal monotone sample S^(M,k) (paper §5).

Key facts implemented here:
  Lemma 5.1/5.2:  x in S^(M,k) <=> x in S^(Thresh_{w_x},k)
                  <=> h_x < k where h_x = #{y : w_y >= w_x  and  u_y < u_x}.
  Estimation:     for member x, the conditional inclusion probability is
                  p(w_x) = (k+1)-th smallest u among {y : w_y >= w_x}
                  (or 1 when fewer than k+1 such keys). This equals the
                  paper's "k-th smallest u_y in Y_x = {y != x : w_y >= w_x}"
                  because a member's own u is among the k smallest of the
                  inclusive set, so deleting it shifts k-th -> (k+1)-th.
  Aux keys Z:     the keys realizing those (k+1)-th smallest values for at
                  least one member's weight group, minus S (paper §5).
  Size bound:     E|S^(M,k)| <= k ln n (Thm 5.1) — verified in benchmarks.

Two implementations:
  * ``universal_monotone_ref``  — O(n^2) pairwise oracle (tests, small n).
  * ``universal_monotone_sample`` — production path: one XLA sort by (-w, u)
    + a BLOCKED buffer scan carrying the (k+1) smallest u's seen so far
    (``_buffer_scan``: _SCAN_CHUNK elements per sequential step, each step
    pure cumsum/matmul-shaped vector work; bit-identical to the one-element-
    per-step ``_buffer_scan_ref``). This is paper Algorithm 1 with the
    max-heap replaced by a fixed-shape sorted buffer (TPU adaptation — see
    DESIGN.md §3).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import uniform01

_INF = jnp.float32(jnp.inf)


class UniversalSample(NamedTuple):
    member: jnp.ndarray  # bool [n] — x in S^(M,k)
    prob: jnp.ndarray    # float32 [n] — p(w_x) for members, else 0
    aux: jnp.ndarray     # bool [n] — x in Z (kept for mergeability/estimation)
    h: jnp.ndarray       # int32 [n] — h_x capped at k+1 (diagnostics/capping)


# ---------------------------------------------------------------------------
# O(n^2) oracle
# ---------------------------------------------------------------------------

def universal_monotone_ref(weights, u, active, k: int) -> UniversalSample:
    """Exact pairwise-definition implementation. O(n^2) memory/compute."""
    w = jnp.asarray(weights, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    act = jnp.asarray(active, bool) & (w > 0)
    n = w.shape[0]

    # h_x = #{y active : w_y >= w_x and u_y < u_x}
    ge = act[None, :] & (w[None, :] >= w[:, None])   # [x, y]
    lt = u[None, :] < u[:, None]
    h = jnp.sum(ge & lt, axis=1).astype(jnp.int32)
    member = act & (h < k)

    # p(w_x) = (k+1)-th smallest u among {y : w_y >= w_x} (x included)
    cand = jnp.where(ge, u[None, :], _INF)           # [x, y]
    cand_sorted = jnp.sort(cand, axis=1)
    if n > k:
        g = cand_sorted[:, k]
        g_idx = jnp.argsort(cand, axis=1)[:, k]
    else:
        g = jnp.full((n,), _INF)
        g_idx = jnp.zeros((n,), jnp.int32)
    prob = jnp.where(member, jnp.where(jnp.isfinite(g), g, 1.0), 0.0)

    # Z = {argmin-(k+1) key for some member with p < 1} \ S
    need = member & jnp.isfinite(g)
    marks = jnp.zeros((n,), bool).at[jnp.where(need, g_idx, n)].set(
        True, mode="drop")
    aux = marks & ~member
    return UniversalSample(member=member, prob=prob, aux=aux,
                           h=jnp.minimum(h, k + 1))


# ---------------------------------------------------------------------------
# Production path: sort + (k+1)-buffer scan  (Algorithm 1, TPU-adapted)
# ---------------------------------------------------------------------------

def _buffer_scan_ref(values, indices, k_plus_1: int):
    """Reference (sequential) buffer scan — one lax.scan step per element,
    O(n) sequential steps of O(k) vector work. Kept as the bit-exactness
    oracle for the blocked ``_buffer_scan``; see that docstring for the
    emitted (rank, tail_v, tail_i) contract."""
    slots = jnp.arange(k_plus_1)

    def step(carry, xs):
        buf_v, buf_i = carry
        v, i = xs
        rank = jnp.sum(buf_v < v).astype(jnp.int32)
        do_insert = rank < k_plus_1
        # insert v at position ``rank`` — rank counts STRICTLY smaller
        # entries, so a tied v lands before every equal-valued entry —
        # shifting the suffix right and evicting the current tail slot.
        # On a tie at the capacity boundary (v == buf_v[-1]) the insert
        # still happens: the old tail is evicted, tail_v is unchanged and
        # tail_i becomes the index of the shifted equal-valued entry. The
        # blocked scan reproduces this bit-exactly because its phase-1
        # rank uses the same strict-< count (searchsorted side='left').
        rolled_v = jnp.concatenate([buf_v[:1], buf_v[:-1]])
        rolled_i = jnp.concatenate([buf_i[:1], buf_i[:-1]])
        new_v = jnp.where(slots < rank, buf_v,
                          jnp.where(slots == rank, v, rolled_v))
        new_i = jnp.where(slots < rank, buf_i,
                          jnp.where(slots == rank, i, rolled_i))
        buf_v = jnp.where(do_insert, new_v, buf_v)
        buf_i = jnp.where(do_insert, new_i, buf_i)
        return (buf_v, buf_i), (rank, buf_v[-1], buf_i[-1])

    init = (jnp.full((k_plus_1,), _INF), jnp.full((k_plus_1,), -1, jnp.int32))
    _, (rank, tail_v, tail_i) = jax.lax.scan(
        step, init, (values.astype(jnp.float32), indices.astype(jnp.int32)))
    return rank, tail_v, tail_i


_SCAN_CHUNK = 128  # elements folded per blocked rank-scan step


@partial(jax.jit, static_argnames=("k_plus_1",))
def _buffer_scan(values, indices, k_plus_1: int):
    """Scan ``values`` (processing order) keeping the k_plus_1 smallest so far.

    Per step emits:
      rank   — #{processed before this step with value < v}, exact while
               <= k_plus_1 - 1; == k_plus_1 means "saturated" (>= that many).
      tail_v — buffer's largest kept value AFTER inserting v
               (= the k_plus_1-th smallest processed so far, inf if fewer).
      tail_i — index of the key realizing tail_v (-1 if none).

    BLOCKED implementation, bit-identical to ``_buffer_scan_ref``, built on
    two facts about the sequential buffer:

      * the emitted rank equals min(#{earlier with value < v}, k_plus_1) —
        a capped prefix-smaller-count, independent of buffer dynamics;
      * an element whose rank saturates is NEVER inserted, so the tail
        sequence is a function of the INSERTED subsequence only (expected
        size ~ k ln n for hashed/random processing order, paper Thm 5.1's
        harmonic argument), and a dropped position's tail is that of the
        most recent inserted position (forward fill).

    Phase 1 computes every rank with a chunked scan (n / _SCAN_CHUNK
    sequential steps: carry = the k_plus_1 smallest values so far, one
    searchsorted + one [C, C] masked pairwise count per chunk). Phase 2
    compacts the inserted elements with a cumsum scatter and replays only
    them through ``_buffer_scan_ref`` (a static bound ~4x the expected
    inserted count; in the unlikely overflow — e.g. an adversarial
    near-descending order — a ``lax.cond`` falls back to the full
    sequential replay, preserving exactness).
    """
    n = values.shape[0]
    k1 = k_plus_1
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32),
                jnp.zeros((0,), jnp.int32))
    v = values.astype(jnp.float32)
    ix = indices.astype(jnp.int32)
    bound = _insert_bound(n, k1)
    if bound >= n:  # replay wouldn't compress anything — scan directly
        return _buffer_scan_ref(v, ix, k1)

    # ---- phase 1: ranks --------------------------------------------------
    c = min(_SCAN_CHUNK, n)
    npad = -(-n // c) * c
    vp = (jnp.pad(v, (0, npad - n), constant_values=jnp.inf)
          if npad > n else v)  # inert tail pad; outputs sliced back
    s_idx = jnp.arange(c)
    before = s_idx[:, None] < s_idx[None, :]

    def rank_step(bv, cv):
        # carry bv: the k1 smallest values so far (sorted multiset), so
        # searchsorted == min(#{earlier chunks' values < cv}, k1)
        cc = jnp.searchsorted(bv, cv).astype(jnp.int32)
        within = jnp.sum((cv[:, None] < cv[None, :]) & before, axis=0,
                         dtype=jnp.int32)
        rank = jnp.minimum(cc + within, k1)
        return jnp.sort(jnp.concatenate([bv, cv]))[:k1], rank

    _, rank = jax.lax.scan(rank_step, jnp.full((k1,), _INF),
                           vp.reshape(-1, c))
    rank = rank.reshape(-1)[:n]

    # ---- phase 2: tails from the inserted subsequence --------------------
    ins = rank < k1
    fill = jnp.cumsum(ins) - 1        # per position: last inserted slot
    slot = jnp.where(ins, fill, bound)
    num = fill[-1] + 1
    comp_v = jnp.full((bound,), _INF).at[slot].set(v, mode="drop")
    comp_i = jnp.full((bound,), -1, jnp.int32).at[slot].set(ix, mode="drop")

    def replay_compressed(_):
        _, tv, ti = _buffer_scan_ref(comp_v, comp_i, k1)
        return (jnp.where(fill >= 0, tv[jnp.maximum(fill, 0)], _INF),
                jnp.where(fill >= 0, ti[jnp.maximum(fill, 0)], -1))

    def replay_full(_):
        _, tv, ti = _buffer_scan_ref(v, ix, k1)
        return tv, ti

    tail_v, tail_i = jax.lax.cond(num <= bound, replay_compressed,
                                  replay_full, None)
    return rank, tail_v, tail_i


def _insert_bound(n: int, k1: int) -> int:
    """Static capacity for the inserted subsequence: ~4x the padded
    harmonic bound k1 * (2 + ln(n / k1 + 1)) — an upper bound on the
    expected count k1 * (1 + ln(n / k1)) that stays safe when n ~ k1 —
    rounded up to the 128 quantum (floor 256, ceiling n)."""
    import math
    exp = k1 * (2.0 + math.log(max(n, 2) / max(k1, 1) + 1.0))
    return min(n, max(256, -(-4 * int(exp) // 128) * 128))


def _group_last(sorted_w):
    """For each sorted position, the position of the LAST element with the
    same weight (weight-group end)."""
    n = sorted_w.shape[0]
    pos = jnp.arange(n)
    is_last = jnp.concatenate([sorted_w[1:] != sorted_w[:-1],
                               jnp.ones((1,), bool)])
    cand = jnp.where(is_last, pos, n - 1 + jnp.zeros((n,), jnp.int32))
    # backward running min propagates each group-end to its whole group
    return jax.lax.cummin(jnp.where(is_last, pos, n), axis=0, reverse=True)


@partial(jax.jit, static_argnames=("k",))
def universal_monotone_sample(keys, weights, active, k: int,
                              seed=0, u=None) -> UniversalSample:
    """S^(M,k) over a fixed-shape batch: O(n log n) sort + blocked scan.

    jit-compiled per (shape, k): host callers get one dispatch; jitted
    callers (merge/sketch rebuilds) inline it into the enclosing trace.
    """
    w = jnp.asarray(weights, jnp.float32)
    act = jnp.asarray(active, bool) & (w > 0)
    if u is None:
        u = uniform01(keys, seed)
    u = jnp.asarray(u, jnp.float32)
    n = w.shape[0]

    # inactive keys: push to the very end and never count them
    sort_w = jnp.where(act, w, -_INF)
    order = jnp.lexsort((u, -sort_w))          # primary: -w asc (w desc); tie: u asc
    sw, su, sact = sort_w[order], u[order], act[order]

    rank, tail_v, tail_i = _buffer_scan(jnp.where(sact, su, _INF),
                                        jnp.arange(n)[order], k + 1)
    h = jnp.minimum(rank, k + 1)
    s_member = sact & (rank < k)

    # p(w) snapshot at each weight-group end: (k+1)-th smallest u among all
    # keys with weight >= w (ties fully processed by group end).
    gl = _group_last(sw)
    g_v = tail_v[gl]
    g_i = tail_i[gl]
    s_prob = jnp.where(s_member, jnp.where(jnp.isfinite(g_v), g_v, 1.0), 0.0)

    # Z: keys realizing a finite group-end tail for a member's group
    need = s_member & jnp.isfinite(g_v)
    marks = jnp.zeros((n,), bool).at[jnp.where(need, g_i, n)].set(
        True, mode="drop")

    # scatter back to original order
    member = jnp.zeros((n,), bool).at[order].set(s_member)
    prob = jnp.zeros((n,), jnp.float32).at[order].set(s_prob)
    h_out = jnp.zeros((n,), jnp.int32).at[order].set(h.astype(jnp.int32))
    aux = marks & ~member
    return UniversalSample(member=member, prob=prob, aux=aux, h=h_out)


def expected_size_bound(n: int, k: int) -> float:
    """Thm 5.1: E|S^(M,k)| <= sum_i min(1, k/i) < k (1 + ln n)."""
    import math
    return float(sum(min(1.0, k / i) for i in range(1, n + 1)))
