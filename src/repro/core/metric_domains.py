"""Metric-domain universal samples (paper §7).

For points X in a metric space and query-indexed objective families
    f_q(x) = d(q, x)^mu        (centrality / average-distance queries)
    f_{q,r}(x) = 1[d(q,x) <= r]  (ball density)
a universal sample must provide gold-standard estimates for EVERY query
point q simultaneously. Following Chechik–Cohen–Kaplan (paper [6]), we
compute sampling probabilities p_x that upper-bound the per-query pps
probabilities using a small set of anchor points: for anchors A and any q,
triangle inequality gives d(q,x)^mu <= 2^mu (d(q,a)^mu + d(a,x)^mu), so
p_x = min(1, k * max_a overline{p}_x^{(a)}) with a constant-factor size
overhead (independent of |X|) — the "(i) size overhead, (ii) efficiency"
program of §7.

Estimates: Q^(f_q, H) = sum_{x in S ∩ H} f_q(x) / p_x (HT, Eq. 2) — for
centrality sum_{x} d(q,x)^mu and for ball density |B(q,r) ∩ X|.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import uniform01


class MetricSample(NamedTuple):
    member: jnp.ndarray   # bool [n]
    prob: jnp.ndarray     # float32 [n] — query-uniform upper-bound probs
    anchors: jnp.ndarray  # int32 [m] — anchor indices


def _pairwise_dist(X, Y):
    d2 = (jnp.sum(X * X, 1)[:, None] + jnp.sum(Y * Y, 1)[None, :]
          - 2 * X @ Y.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def universal_metric_sample(X, k: int, mu: float = 1.0, n_anchors: int = 8,
                            seed: int = 0) -> MetricSample:
    """One sample serving f_q(x) = d(q,x)^mu for ALL queries q.

    X: [n, dim] points. Anchors are a greedy 2-approx k-center net (farthest
    point traversal) — the 'few distance queries' construction of §7.
    """
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    # farthest-point anchors
    anchors = [0]
    d_min = _pairwise_dist(X, X[:1]).reshape(-1)
    for _ in range(n_anchors - 1):
        nxt = int(jnp.argmax(d_min))
        anchors.append(nxt)
        d_min = jnp.minimum(d_min, _pairwise_dist(X, X[nxt:nxt + 1]).reshape(-1))
    A = jnp.asarray(anchors, jnp.int32)

    # per-anchor pps probabilities for f_a(x) = (d(a,x)+eps)^mu; the max over
    # anchors upper-bounds (up to the triangle-inequality constant) the pps
    # probability for every query q
    D = _pairwise_dist(X, X[A])                     # [n, m]
    eps = jnp.mean(D) * 1e-3 + 1e-12
    fv = jnp.power(D + eps, mu)                     # [n, m]
    p_a = fv / jnp.sum(fv, axis=0, keepdims=True)   # per-anchor pps
    p = jnp.minimum(1.0, (2.0 ** mu) * k * jnp.max(p_a, axis=1))
    u = uniform01(jnp.arange(n, dtype=jnp.int32), seed)
    return MetricSample(member=(u < p), prob=p, anchors=A)


def estimate_centrality(sample: MetricSample, X, q, mu: float = 1.0):
    """HT estimate of sum_x d(q, x)^mu from the universal sample."""
    X = jnp.asarray(X, jnp.float32)
    q = jnp.asarray(q, jnp.float32).reshape(1, -1)
    d = _pairwise_dist(X, q).reshape(-1)
    contrib = jnp.where(sample.member,
                        jnp.power(d, mu) / jnp.maximum(sample.prob, 1e-30),
                        0.0)
    return jnp.sum(contrib)


def estimate_ball_density(sample: MetricSample, X, q, r: float):
    """HT estimate of |{x : d(q,x) <= r}| from the same sample."""
    X = jnp.asarray(X, jnp.float32)
    q = jnp.asarray(q, jnp.float32).reshape(1, -1)
    d = _pairwise_dist(X, q).reshape(-1)
    contrib = jnp.where(sample.member & (d <= r),
                        1.0 / jnp.maximum(sample.prob, 1e-30), 0.0)
    return jnp.sum(contrib)
