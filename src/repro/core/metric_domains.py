"""Metric-domain universal samples (paper §7).

For points X in a metric space and query-indexed objective families
    f_q(x) = d(q, x)^mu        (centrality / average-distance queries)
    f_{q,r}(x) = 1[d(q,x) <= r]  (ball density)
a universal sample must provide gold-standard estimates for EVERY query
point q simultaneously. Following Chechik–Cohen–Kaplan (paper [6]), we
compute sampling probabilities p_x that upper-bound the per-query pps
probabilities using a small set of anchor points: for anchors A and any q,
triangle inequality gives d(q,x)^mu <= 2^mu (d(q,a)^mu + d(a,x)^mu), so
p_x = min(1, k * max_a overline{p}_x^{(a)}) with a constant-factor size
overhead (independent of |X|) — the "(i) size overhead, (ii) efficiency"
program of §7.

The sample itself is a ``MultiSketch``: the target probabilities are fed
as WEIGHTS into a single-objective (SUM, k_eff) bottom-k build with
k_eff = ceil(sum_x p_x) — the standard ppswor realization of a pps design,
whose conditional inclusion probabilities (Eq. 3) are exact for HT — so
the metric sample inherits the whole slab stack: device-resident absorb,
exact merge, checkpointing, and the fused service-cost kernel
(kernels.servicecost) via the coords-aligned ``ClusterEngine``
(launch.cluster). ``universal_metric_sample`` scatters the slab back to a
dense [n] mask for the classic per-point API.

Anchors come from a jit'd farthest-point traversal (``lax.fori_loop``,
zero host↔device syncs) — the 'few distance queries' construction of §7.

Estimates: Q^(f_q, H) = sum_{x in S ∩ H} f_q(x) / p_x (HT, Eq. 2) — for
centrality sum_{x} d(q,x)^mu and for ball density |B(q,r) ∩ X|.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .costs import sq_dists
from .funcs import SUM
from .multi_sketch import MultiSketch, MultiSketchSpec, multisketch_build


class MetricSample(NamedTuple):
    member: jnp.ndarray   # bool [n]
    prob: jnp.ndarray     # float32 [n] — conditional HT probabilities
    anchors: jnp.ndarray  # int32 [m] — anchor indices


class MetricSketch(NamedTuple):
    """Slab-format metric sample: the sketch plus the coordinates of its
    slots — everything the fused service-cost kernel consumes."""
    sketch: MultiSketch   # slab over keys = point indices
    coords: jnp.ndarray   # float32 [cap, dim] — X[key] per slot (0 invalid)
    anchors: jnp.ndarray  # int32 [m]


def _pairwise_dist(X, Y):
    # the shared quadratic-expansion distance of the cost path — anchors,
    # probs and service costs must never diverge on clamping/regularization
    return jnp.sqrt(sq_dists(jnp.asarray(X, jnp.float32),
                             jnp.asarray(Y, jnp.float32)))


@partial(jax.jit, static_argnames=("m",))
def farthest_point_anchors(X, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy 2-approx k-center net (farthest-point traversal) from point 0.

    ONE jit'd ``lax.fori_loop`` — no per-anchor host↔device sync. Returns
    (anchors int32 [m], d_min float32 [n] = distance to the nearest anchor),
    numerically identical to the sequential host loop (same per-anchor
    distance columns, same argmax tie-breaks).
    """
    X = jnp.asarray(X, jnp.float32)
    dim = X.shape[1]
    d_min0 = _pairwise_dist(X, X[:1]).reshape(-1)

    def body(j, carry):
        anchors, d_min = carry
        nxt = jnp.argmax(d_min).astype(jnp.int32)
        xa = jax.lax.dynamic_slice(X, (nxt, 0), (1, dim))
        d_min = jnp.minimum(d_min, _pairwise_dist(X, xa).reshape(-1))
        return anchors.at[j].set(nxt), d_min

    anchors, d_min = jax.lax.fori_loop(
        1, m, body, (jnp.zeros((m,), jnp.int32), d_min0))
    return anchors, d_min


def anchor_upper_weights(X, anchor_coords, mu: float, eps=None, norm=None):
    """Per-point universal upper-bound weights v_x = max_a p̄_x^{(a)}.

    For each anchor a, p̄^{(a)} is the pps distribution of
    f_a(x) = (d(a,x)+eps)^mu; by the triangle inequality max_a p̄^{(a)}_x
    upper-bounds (up to the 2^mu constant) the pps probability of every
    query q. ``eps``/``norm`` ([m] per-anchor column sums) default to this
    batch's own statistics; a streaming caller (launch.cluster) freezes
    them at the first chunk so weights stay comparable across chunks —
    ppswor seeds r/w are only coordinated under a fixed normalization.

    Returns (v [n], eps, norm).
    """
    D = _pairwise_dist(jnp.asarray(X, jnp.float32),
                       jnp.asarray(anchor_coords, jnp.float32))   # [n, m]
    if eps is None:
        eps = jnp.mean(D) * 1e-3 + 1e-12
    fv = jnp.power(D + eps, mu)
    if norm is None:
        norm = jnp.sum(fv, axis=0)
    v = jnp.max(fv / norm[None, :], axis=1)
    return v, eps, norm


def metric_sample_sketch(X, k: int, mu: float = 1.0, n_anchors: int = 8,
                         seed: int = 0, scheme: str = "ppswor"
                         ) -> Tuple[MetricSketch, MultiSketchSpec]:
    """One slab serving f_q(x) = d(q,x)^mu for ALL queries q.

    X: [n, dim] points. The anchor-based upper-bound probabilities
    p_x = min(1, 2^mu k v_x) become the weights of a (SUM, k_eff) bottom-k
    MultiSketch with k_eff = ceil(sum p_x) — same expected size as the
    classic Bernoulli mask, but mergeable, checkpointable and directly
    consumable by the fused service-cost kernel.
    """
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    anchors, _ = farthest_point_anchors(X, min(n_anchors, n))
    v, _, _ = anchor_upper_weights(X, X[anchors], mu)
    p_t = jnp.minimum(1.0, (2.0 ** mu) * k * v)
    k_eff = max(2, int(np.ceil(float(jnp.sum(p_t)))))
    spec = MultiSketchSpec(objectives=((SUM, k_eff),), scheme=scheme,
                           seed=seed)
    # runtime-seed build: one compiled executable across seeds (spec.seed
    # stays the mergeability metadata; the hash is keyed by ``seed``)
    sk = multisketch_build(dataclasses.replace(spec, seed=0),
                           jnp.arange(n, dtype=jnp.int32), p_t, seed=seed)
    slot = jnp.clip(sk.keys, 0, n - 1)
    coords = jnp.where(sk.valid[:, None], X[slot], 0.0)
    return MetricSketch(sketch=sk, coords=coords, anchors=anchors), spec


def universal_metric_sample(X, k: int, mu: float = 1.0, n_anchors: int = 8,
                            seed: int = 0) -> MetricSample:
    """Dense-mask view of :func:`metric_sample_sketch` (classic §7 API):
    member/prob scattered from the slab back over the n points."""
    X = jnp.asarray(X, jnp.float32)
    n = X.shape[0]
    ms, _ = metric_sample_sketch(X, k, mu=mu, n_anchors=n_anchors, seed=seed)
    sk = ms.sketch
    at = jnp.where(sk.valid & sk.member, sk.keys, n)
    member = jnp.zeros((n,), bool).at[at].set(True, mode="drop")
    prob = jnp.zeros((n,), jnp.float32).at[at].set(sk.probs, mode="drop")
    return MetricSample(member=member, prob=prob, anchors=ms.anchors)


def estimate_centrality(sample: MetricSample, X, q, mu: float = 1.0):
    """HT estimate of sum_x d(q, x)^mu from the universal sample."""
    X = jnp.asarray(X, jnp.float32)
    q = jnp.asarray(q, jnp.float32).reshape(1, -1)
    d = _pairwise_dist(X, q).reshape(-1)
    contrib = jnp.where(sample.member,
                        jnp.power(d, mu) / jnp.maximum(sample.prob, 1e-30),
                        0.0)
    return jnp.sum(contrib)


def estimate_ball_density(sample: MetricSample, X, q, r: float):
    """HT estimate of |{x : d(q,x) <= r}| from the same sample."""
    X = jnp.asarray(X, jnp.float32)
    q = jnp.asarray(q, jnp.float32).reshape(1, -1)
    d = _pairwise_dist(X, q).reshape(-1)
    contrib = jnp.where(sample.member & (d <= r),
                        1.0 / jnp.maximum(sample.prob, 1e-30), 0.0)
    return jnp.sum(contrib)
