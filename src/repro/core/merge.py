"""Mergeable fixed-capacity sketches (paper §2.5, §3.3, §5.2 composability).

A ``Sketch`` is the wire/state format of a universal monotone sample: a
fixed-capacity array of (key, weight, u) triples covering S ∪ Z plus validity
bits. Fixed capacity makes sketches jit-compatible and collective-friendly:
merging across shards is an ``all_gather`` + re-selection, and merging across
time (streaming) is a concat + re-selection. Both are EXACT: the paper proves
S∪Z of a union is contained in the union of the parts' S∪Z sets, so
re-running selection on concatenated retained keys reproduces the sample the
union data set would have produced.

u_x comes from the shared hash (core.hashing), so the same key sampled on two
shards carries the same u — the coordination requirement.

The MULTI-OBJECTIVE counterpart lives in core.multi_sketch: ``MultiSketch``
is the fixed-capacity wire format for S^(F) ∪ Z of a multi-objective
bottom-k sample, with static half ``MultiSketchSpec`` (objectives (f, k_f),
scheme, hash seed, capacity). Wire layout: keys/weights/probs/member/aux/
valid slabs [capacity] plus per-objective seeds [|F|, capacity] and taus
[|F|]. Its merge invariants:

  * coordination — all parts hash u_x from the same (key, spec.seed), so
    per-objective samples of a union are unions of per-part samples;
  * threshold closure — each sketch retains in Z the threshold key (the
    arg of tau^(f,k_f)) of EVERY objective, so the union's (k_f+1)-th
    smallest f-seed is always present among the parts' retained keys;
  * max-weight dedup — a key retained by several parts keeps max w_x
    (the paper's weight of a merged data set).

  Under these, re-selection over concatenated retained slabs reproduces
  member set, p^(F) AND taus of the union sample exactly, for any chunking
  (streaming ``multisketch_absorb``) and any shard fan-in (``all_gather`` +
  ``multisketch_merge_stacked``). Capacity sum_f k_f + |F| suffices always.

``sketch_estimate`` below is the single HT-estimate implementation shared
by both formats (they agree on the member/weights/probs/keys fields).

QUERY-ENGINE CONTRACT (launch.query.SegmentQueryEngine + kernels.segquery):
serving reads a sketch through batched segment queries, and the merge
invariants above are exactly what make that correct:

  * a query batch is B predicate rows x |F| objectives evaluated against
    ONE merged slab in ONE kernel launch; each estimate is the same HT sum
    as ``sketch_estimate`` (sum over member slots of f(w)/p restricted to
    the segment), so per-objective CV guarantees (Thm 3.1) apply per row;
  * predicates use the int32 wire format of core.predicates — one row
    [lo, hi, mask, want, salt, flags] meaning
    ``lo <= v <= hi and (v & mask) == want`` with v = key, or
    v = hash31(key, salt) when flags bit 0 (ON_HASH) is set. hash31 is the
    top 31 bits of the shared key hash, so ON_HASH rows select the SAME
    uniform key fraction on every shard/host (coordination);
  * the engine keeps per-shard slabs resident and materializes the merged
    slab lazily, memoized per absorb epoch. Because merging is EXACT (the
    invariants above), a lazily-merged answer is bit-identical to querying
    the eager ``launch.summary.sharded_multisketch`` result, for any
    absorb/merge interleaving;

  INCREMENTAL-MERGE CONTRACT (dirty-epoch semantics). The engine tracks,
  per shard, the epoch of its last mutation and the epoch snapshot its
  cached merged slab reflects. When an epoch's dirty set is a strict
  subset of the shards (bounded by ``max_delta``), the merged slab is
  maintained INCREMENTALLY: the dirty shards' slabs are folded straight
  into the cached merged slab (``multi_sketch.multisketch_absorb_into`` —
  one (1 + |dirty|) x capacity re-selection, cached-slab buffers donated)
  instead of re-running ``merge_stacked`` over all S shards. Exactness is
  the same threshold-closure argument: the cached slab summarizes the
  union U of ALL shards' data at snapshot time, each dirty slab
  summarizes its shard's current data D_i, and absorbs only ADD data, so
  sketch(U ∪ (∪ D_i)) — what the delta fold re-selects — is the sketch
  of the same union data set the full re-merge would summarize:
  BIT-IDENTICAL, asserted across schemes and |F| in the test tier. The
  contract's preconditions, enforced by the engine:
    * monotone history — ``set_shard``/``load_stacked`` REPLACE shard
      content (old keys may vanish from the union), so they drop the
      cache and force the next merge down the full path;
    * non-truncating capacity (>= the spec default) — a truncated
      compaction voids exact merging, so delta and full results could
      legitimately diverge; the engine then always re-merges fully;
    * donated-buffer discipline — the delta fold consumes only
      engine-owned merged-slab buffers; a slab handed out via the public
      ``merged`` property is re-pointed (copied) first, and resident
      shard slabs ride the delta WITHOUT donation.

  ABSORB-TIME MAINTENANCE (zero-merge serving, the engine default).
  The same delta fold can run one query early: after the shard fold of
  an absorb, the POST-FOLD shard slab is folded into the cached merged
  slab in the same donated epoch, so the cache is already current when
  the next query arrives — the query path dispatches ZERO merge work
  (asserted by dispatch-count spies in the test tier and the bench-smoke
  CI gate). Exactness is the incremental contract verbatim (the shard
  slab summarizes a superset of the delta; max-weight dedup makes
  re-folding its older rows a no-op), under the same preconditions:
  maintenance only runs while the cache is current, the history is
  monotone and capacity is non-truncating — any violation falls back to
  the lazy ladder and reseeds maintenance at the next full merge. The
  MERGED SLAB IS AUTHORITATIVE between epochs: queries never consult
  shard slabs directly, so quarantine (rejected NaN/negative rows never
  reach a fold) and the ``overflow`` flag — refreshed at most once per
  epoch at query time, never on the absorb path, which must not pay a
  device sync — both describe the merged slab the answers came from.

  BIT-IDENTITY MECHANISM (``multisketch_finalize``). Value-exactness of
  every path above is the threshold-closure argument; BIT-exactness of
  ``probs`` additionally requires one canonical program for the
  inclusion probability, because XLA codegens transcendentals with
  shape-dependent last-ulp rounding (a [c] delta fold and a [m, c]
  stacked re-merge can disagree by one ulp on the same slab). Every
  host-level producer therefore overwrites probs with the fixed-shape
  spec-keyed finalizer after compaction; in-trace producers
  (``multisketch_absorb_inline``, shard_map interiors) are finalized at
  their host-level boundary (``launch.summary.sharded_multisketch``).

  SHARD LIFECYCLE (GC / evict / spill). Long-running engines bound live
  shard count and device bytes: ``gc`` folds cold shards (oldest
  last-absorb epoch first, under ``max_live``/``min_age`` water-marks)
  into the compacted BASE slab (shard 0) with the same exact delta fold,
  parks victims on a shared inert slab and truncates trailing dead
  shards — the union is unchanged, so a current merged cache is
  re-stamped across the GC epoch, never re-merged, and answers are
  bit-identical to keeping the shards separate. ``gc_plan`` is pure and
  deterministic in the absorb history, so a serving tier can WAL the
  victim list BEFORE applying (apply-then-append, launch.wal GC
  markers): replay reproduces the RECORDED decision and lands in the
  identical post-GC state; a marker lost to a crash merely replays into
  the pre-GC layout, whose merged slab is bit-identical. ``spill``
  persists victim slabs through ckpt.manager first, so evicted shards
  can be re-adopted later (``from_checkpoint`` + ``add_shard``) —
  a long-running ``EnginePool`` stream holds O(capacity) device memory,
  with ``merge_stats`` gauges (live_shards, gc_merges, bytes_resident)
  exposed through pool responses and telemetry.

  * slabs are plain arrays, so CHECKPOINTING is ``ckpt.manager`` over the
    shard list plus the spec stored as JSON extra-metadata
    (``multi_sketch.spec_to_meta``); ``SegmentQueryEngine.from_checkpoint``
    reconstructs the spec first, restores the crc-verified slabs into it,
    and — by the same exactness — a restored-then-merged engine (cross-job
    fan-in via ``add_shard``) answers exactly like a one-shot build over
    the union data set.

SERVICE-COST WIRE FORMAT (core.costs + kernels.servicecost): the metric
domain (paper §7) replaces key predicates with CENTER-SET queries — a
query is (centers [Q, Cmax, dim], cvalid [Q, Cmax], mu, param, mode) rows
where mode selects min-dist^mu clustering cost or the radius-r ball
indicator; center sets are runtime data (an optimizer proposes them), so
the wire format is arrays, not static rows. An all-invalid row estimates
exactly 0 (the Q-bucket padding element). ``core.costs.
service_cost_values`` defines the semantics; the fused kernel evaluates
the identical function in one launch (centers on sublanes, slab slots on
lanes), flat in both Q and Cmax.

CLUSTER-ENGINE CONTRACT (launch.cluster.ClusterEngine): the metric twin of
the query engine. Resident state is a MultiSketch over point keys whose
weights are the anchor-based universal upper-bound probabilities
(core.metric_domains) PLUS a coords slab realigned slot-by-slot after
every donated fold — so the fused service-cost kernel reads coordinates,
probs and member bits from the same resident arrays. Anchor normalizers
freeze at the first chunk (ppswor seeds are only coordinated under a
fixed normalization), every absorb bumps an epoch counter (the external
staleness signal, mirroring the query engine — queries always read the
live slab), and every estimate is the same HT sum as
``sketch_estimate`` with f_C(x) in place of f(w_x) — so per-objective CV
guarantees carry over to every candidate center set the optimizer scores.

SERVING-TIER FAILURE-SEMANTICS CONTRACT (launch.pool.EnginePool): the
multi-tenant serving tier leans on the exactness invariants above to
degrade WITHOUT becoming wrong. Its rules:

  * degradation ladder — every response is labeled FRESH (live merged
    slab, epoch_lag == 0), STALE (answered, but from the last-good merged
    slab and/or with accepted-but-unfolded chunks; ``epoch_lag`` counts
    exactly how many), or REJECTED (admission queue full, deadline
    passed, or no last-good slab to fall back to). There is no fourth
    state: an answer the pool cannot label is an answer it refuses.
  * staleness is never wrongness — a stale merged slab is still an EXACT
    multi-objective sketch of a PREFIX of the stream (merging exactness
    above), so every HT estimate served from it is unbiased for that
    prefix with the full Thm 3.1 per-objective CV guarantee; ``epoch_lag``
    tells the caller which prefix. The ``overflow`` flag rides along so a
    capacity-saturated (guarantee-voiding) slab is always visible.
  * durability & replay exactness — ingest is WAL-appended (crc-framed,
    fsync'd) BEFORE the device fold, and snapshots store the slabs plus
    the applied WAL sequence. Crash recovery = restore newest intact
    snapshot -> replay the WAL tail in sequence order -> BIT-IDENTICAL
    slabs to the uncrashed engine: the fold is deterministic and absorb
    order was fixed by the WAL, so this is the same streaming-exactness
    argument as ``multisketch_absorb``. Corrupt snapshots fall back a
    step (crc catch) and replay a longer tail; torn WAL tails drop only
    the final, unacknowledged record.
  * quarantine — non-finite/negative weights and out-of-range keys are
    masked per ROW (inactive, key -1, weight 0) before the fold, which
    makes them indistinguishable from ``pad_chunk`` padding: the slab is
    bit-identical to one that absorbed only the clean rows, and the
    quarantine count is surfaced per absorb receipt and per stream.

SCALE-OUT CONTRACT (launch.pool.ShardedEnginePool): the multi-host tier
adds machine loss and re-partitioning on top of the ladder above, still
without a fourth answer state. Its rules:

  * cross-host exactness — shards are placed by rendezvous hash over the
    host group; each owner folds its shards locally and a read merges the
    per-host MERGED slabs through the same shared fold family as a
    single-host engine (composability is transitive through intermediate
    merges, paper §3.3, and compaction is deterministic in the retained
    multiset) — so the group answer is BIT-IDENTICAL to a never-sharded
    union engine over the same records, not merely unbiased.
  * REBALANCE markers — a re-partition applies the shard hand-offs
    first, THEN appends one REBALANCE marker (launch.wal, shard == -2)
    whose payload is the FULL new placement: the same apply-then-append
    discipline as GC markers. Replay dispatches markers in sequence
    order, so recovery lands every record on the owner the marker
    recorded; a marker lost or torn by a crash merely recovers the
    PRE-move placement — a different partition of the SAME union, whose
    merged answers are bit-identical (merging exactness above). Dead
    hosts' shards are rebuilt from newest intact checkpoint + full WAL
    tail (GC markers included — GC moves mass across shards, so a
    filtered replay would be wrong).
  * replica promotion — every FRESH answer's merged slab is copied, with
    its applied sequence, to the top-2 rendezvous-ranked live hosts for
    the stream. When an owner dies, reads fall back to the newest
    surviving replica at STALE with ``epoch_lag`` = acks since that
    slab; losing every replica holder is REJECTED, never a guess.
    Accepted-but-unappliable chunks stay WAL-durable in a bounded
    pending backlog (sheds at ``pending_limit``) and fold on rebalance.
    The cluster tier mirrors this with ``ClusterEngine.handoff``: the
    replica carries the FROZEN anchor normalizers, so a promoted
    follower keeps absorbing sample-coordinated with the source, bit
    for bit — re-deriving anchors on promotion would silently decouple
    the samples.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import uniform01
from .universal import UniversalSample, universal_monotone_sample

_INF = jnp.float32(jnp.inf)


class Sketch(NamedTuple):
    keys: jnp.ndarray     # int32 [c] — key ids (-1 for empty slots)
    weights: jnp.ndarray  # float32 [c]
    probs: jnp.ndarray    # float32 [c] — p(w) for members (0 otherwise)
    member: jnp.ndarray   # bool [c] — in S (vs auxiliary-only in Z)
    valid: jnp.ndarray    # bool [c]
    k: int                # sample-size parameter (static)
    seed: int             # hash seed (static; must match to merge)


def sketch_capacity(n_hint: int, k: int) -> int:
    """Suggested capacity ~ 2 k ln n (Thm 5.1 bound + slack for Z)."""
    import math
    return int(2 * k * max(2.0, math.log(max(n_hint, 4))) + 2 * k)


def build_sketch(keys, weights, active, k: int, capacity: int,
                 seed: int = 0) -> Sketch:
    """Compute S^(M,k) over a batch and compact S ∪ Z into a Sketch."""
    s = universal_monotone_sample(keys, weights, active, k, seed=seed)
    return _compact(keys, weights, s, k, capacity, seed)


def _compact(keys, weights, s: UniversalSample, k: int, capacity: int,
             seed: int) -> Sketch:
    keep = s.member | s.aux
    # order: kept first (members before aux), then by weight desc
    order = jnp.lexsort((-jnp.asarray(weights, jnp.float32), ~s.member, ~keep))
    n = order.shape[0]
    if n < capacity:  # pad so every sketch carries exactly `capacity` slots
        order = jnp.concatenate([order, jnp.zeros(capacity - n, order.dtype)])
        pad_valid = jnp.arange(capacity) < n
    else:
        order = order[:capacity]
        pad_valid = jnp.ones((capacity,), bool)
    take = order
    kk = jnp.asarray(keys, jnp.int32)[take]
    keep_t = keep[take] & pad_valid
    return Sketch(
        keys=jnp.where(keep_t, kk, -1),
        weights=jnp.where(keep_t, jnp.asarray(weights, jnp.float32)[take],
                          0.0),
        probs=jnp.where(keep_t, s.prob[take], 0.0),
        member=s.member[take] & keep_t,
        valid=keep_t,
        k=k, seed=seed)


def _merge_core(ak, aw, av, bk, bw, bv, *, k, capacity, seed):
    s = _rebuild(jnp.concatenate([ak, bk]), jnp.concatenate([aw, bw]),
                 jnp.concatenate([av, bv]), k, capacity, seed)
    return s.keys, s.weights, s.probs, s.member, s.valid


_merge_jit = partial(jax.jit, static_argnames=("k", "capacity", "seed"))(
    _merge_core)
# the donated variant reuses both input slabs' buffers for the result —
# for fold-style callers (state <- merge(state, new)) that never touch the
# inputs again; re-using a donated slab is an error by design
_merge_jit_donated = partial(jax.jit,
                             static_argnames=("k", "capacity", "seed"),
                             donate_argnums=(0, 1, 2, 3, 4, 5))(_merge_core)


def merge_sketches(a: Sketch, b: Sketch, donate: bool = False) -> Sketch:
    """Merge two sketches (same k/seed): concat, dedup (keep max weight),
    re-select. Exact per paper §5.2.

    jit-cached per (k, capacity, seed, shapes) — repeated merges under one
    spec reuse a single compiled executable. ``donate=True`` additionally
    donates BOTH input slabs' device buffers to the output (zero
    steady-state allocation for streaming folds); the inputs must not be
    used afterwards.
    """
    assert a.k == b.k and a.seed == b.seed, "sketches must share k and hash seed"
    fn = _merge_jit_donated if donate else _merge_jit
    keys, weights, probs, member, valid = fn(
        a.keys, a.weights, a.valid, b.keys, b.weights, b.valid,
        k=a.k, capacity=a.keys.shape[0], seed=a.seed)
    return Sketch(keys=keys, weights=weights, probs=probs, member=member,
                  valid=valid, k=a.k, seed=a.seed)


def merge_many(sketches_keys, sketches_weights, sketches_valid, k: int,
               capacity: int, seed: int) -> Sketch:
    """Merge a stacked batch of sketches [m, c] -> one sketch (tree-free,
    single re-selection). Used after all_gather over the mesh."""
    return _rebuild(sketches_keys.reshape(-1), sketches_weights.reshape(-1),
                    sketches_valid.reshape(-1), k, capacity, seed)


def _rebuild(keys, weights, valid, k: int, capacity: int, seed: int) -> Sketch:
    # dedup by key keeping max weight (paper: w_x = max over elements)
    order = jnp.lexsort((-weights, keys))
    sk, sw, sv = keys[order], weights[order], valid[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    act = sv & ~dup & (sk >= 0)
    s = universal_monotone_sample(sk, sw, act, k, seed=seed)
    return _compact(sk, sw, s, k, capacity, seed)


def sketch_estimate(sk, f, segment_fn=None) -> jnp.ndarray:
    """HT estimate of Q(f, H) from a sketch (``Sketch`` or ``MultiSketch`` —
    any record with member/weights/probs/keys fields).

    segment_fn: optional vectorized predicate over keys selecting the
    segment H (default: the whole data set).
    """
    member = sk.member
    if segment_fn is not None:
        member = member & jnp.asarray(segment_fn(sk.keys), bool)
    contrib = jnp.where(member,
                        f(sk.weights) / jnp.maximum(sk.probs, 1e-30), 0.0)
    return jnp.sum(contrib)
