"""Mergeable fixed-capacity sketches (paper §2.5, §3.3, §5.2 composability).

A ``Sketch`` is the wire/state format of a universal monotone sample: a
fixed-capacity array of (key, weight, u) triples covering S ∪ Z plus validity
bits. Fixed capacity makes sketches jit-compatible and collective-friendly:
merging across shards is an ``all_gather`` + re-selection, and merging across
time (streaming) is a concat + re-selection. Both are EXACT: the paper proves
S∪Z of a union is contained in the union of the parts' S∪Z sets, so
re-running selection on concatenated retained keys reproduces the sample the
union data set would have produced.

u_x comes from the shared hash (core.hashing), so the same key sampled on two
shards carries the same u — the coordination requirement.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import uniform01
from .universal import UniversalSample, universal_monotone_sample

_INF = jnp.float32(jnp.inf)


class Sketch(NamedTuple):
    keys: jnp.ndarray     # int32 [c] — key ids (-1 for empty slots)
    weights: jnp.ndarray  # float32 [c]
    probs: jnp.ndarray    # float32 [c] — p(w) for members (0 otherwise)
    member: jnp.ndarray   # bool [c] — in S (vs auxiliary-only in Z)
    valid: jnp.ndarray    # bool [c]
    k: int                # sample-size parameter (static)
    seed: int             # hash seed (static; must match to merge)


def sketch_capacity(n_hint: int, k: int) -> int:
    """Suggested capacity ~ 2 k ln n (Thm 5.1 bound + slack for Z)."""
    import math
    return int(2 * k * max(2.0, math.log(max(n_hint, 4))) + 2 * k)


def build_sketch(keys, weights, active, k: int, capacity: int,
                 seed: int = 0) -> Sketch:
    """Compute S^(M,k) over a batch and compact S ∪ Z into a Sketch."""
    s = universal_monotone_sample(keys, weights, active, k, seed=seed)
    return _compact(keys, weights, s, k, capacity, seed)


def _compact(keys, weights, s: UniversalSample, k: int, capacity: int,
             seed: int) -> Sketch:
    keep = s.member | s.aux
    # order: kept first (members before aux), then by weight desc
    order = jnp.lexsort((-jnp.asarray(weights, jnp.float32), ~s.member, ~keep))
    n = order.shape[0]
    if n < capacity:  # pad so every sketch carries exactly `capacity` slots
        order = jnp.concatenate([order, jnp.zeros(capacity - n, order.dtype)])
        pad_valid = jnp.arange(capacity) < n
    else:
        order = order[:capacity]
        pad_valid = jnp.ones((capacity,), bool)
    take = order
    kk = jnp.asarray(keys, jnp.int32)[take]
    keep_t = keep[take] & pad_valid
    return Sketch(
        keys=jnp.where(keep_t, kk, -1),
        weights=jnp.where(keep_t, jnp.asarray(weights, jnp.float32)[take],
                          0.0),
        probs=jnp.where(keep_t, s.prob[take], 0.0),
        member=s.member[take] & keep_t,
        valid=keep_t,
        k=k, seed=seed)


def merge_sketches(a: Sketch, b: Sketch) -> Sketch:
    """Merge two sketches (same k/seed): concat, dedup (keep max weight),
    re-select. Exact per paper §5.2."""
    assert a.k == b.k and a.seed == b.seed, "sketches must share k and hash seed"
    keys = jnp.concatenate([a.keys, b.keys])
    weights = jnp.concatenate([a.weights, b.weights])
    valid = jnp.concatenate([a.valid, b.valid])
    return _rebuild(keys, weights, valid, a.k, a.keys.shape[0], a.seed)


def merge_many(sketches_keys, sketches_weights, sketches_valid, k: int,
               capacity: int, seed: int) -> Sketch:
    """Merge a stacked batch of sketches [m, c] -> one sketch (tree-free,
    single re-selection). Used after all_gather over the mesh."""
    return _rebuild(sketches_keys.reshape(-1), sketches_weights.reshape(-1),
                    sketches_valid.reshape(-1), k, capacity, seed)


def _rebuild(keys, weights, valid, k: int, capacity: int, seed: int) -> Sketch:
    # dedup by key keeping max weight (paper: w_x = max over elements)
    order = jnp.lexsort((-weights, keys))
    sk, sw, sv = keys[order], weights[order], valid[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    act = sv & ~dup & (sk >= 0)
    s = universal_monotone_sample(sk, sw, act, k, seed=seed)
    return _compact(sk, sw, s, k, capacity, seed)


def sketch_estimate(sk: Sketch, f) -> jnp.ndarray:
    """HT estimate of Q(f, X) from a sketch."""
    contrib = jnp.where(sk.member, f(sk.weights) / jnp.maximum(sk.probs, 1e-30), 0.0)
    return jnp.sum(contrib)
