"""Mergeable fixed-capacity sketches (paper §2.5, §3.3, §5.2 composability).

A ``Sketch`` is the wire/state format of a universal monotone sample: a
fixed-capacity array of (key, weight, u) triples covering S ∪ Z plus validity
bits. Fixed capacity makes sketches jit-compatible and collective-friendly:
merging across shards is an ``all_gather`` + re-selection, and merging across
time (streaming) is a concat + re-selection. Both are EXACT: the paper proves
S∪Z of a union is contained in the union of the parts' S∪Z sets, so
re-running selection on concatenated retained keys reproduces the sample the
union data set would have produced.

u_x comes from the shared hash (core.hashing), so the same key sampled on two
shards carries the same u — the coordination requirement.

The MULTI-OBJECTIVE counterpart lives in core.multi_sketch: ``MultiSketch``
is the fixed-capacity wire format for S^(F) ∪ Z of a multi-objective
bottom-k sample, with static half ``MultiSketchSpec`` (objectives (f, k_f),
scheme, hash seed, capacity). Wire layout: keys/weights/probs/member/aux/
valid slabs [capacity] plus per-objective seeds [|F|, capacity] and taus
[|F|]. Its merge invariants:

  * coordination — all parts hash u_x from the same (key, spec.seed), so
    per-objective samples of a union are unions of per-part samples;
  * threshold closure — each sketch retains in Z the threshold key (the
    arg of tau^(f,k_f)) of EVERY objective, so the union's (k_f+1)-th
    smallest f-seed is always present among the parts' retained keys;
  * max-weight dedup — a key retained by several parts keeps max w_x
    (the paper's weight of a merged data set).

  Under these, re-selection over concatenated retained slabs reproduces
  member set, p^(F) AND taus of the union sample exactly, for any chunking
  (streaming ``multisketch_absorb``) and any shard fan-in (``all_gather`` +
  ``multisketch_merge_stacked``). Capacity sum_f k_f + |F| suffices always.

``sketch_estimate`` below is the single HT-estimate implementation shared
by both formats (they agree on the member/weights/probs/keys fields).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .hashing import uniform01
from .universal import UniversalSample, universal_monotone_sample

_INF = jnp.float32(jnp.inf)


class Sketch(NamedTuple):
    keys: jnp.ndarray     # int32 [c] — key ids (-1 for empty slots)
    weights: jnp.ndarray  # float32 [c]
    probs: jnp.ndarray    # float32 [c] — p(w) for members (0 otherwise)
    member: jnp.ndarray   # bool [c] — in S (vs auxiliary-only in Z)
    valid: jnp.ndarray    # bool [c]
    k: int                # sample-size parameter (static)
    seed: int             # hash seed (static; must match to merge)


def sketch_capacity(n_hint: int, k: int) -> int:
    """Suggested capacity ~ 2 k ln n (Thm 5.1 bound + slack for Z)."""
    import math
    return int(2 * k * max(2.0, math.log(max(n_hint, 4))) + 2 * k)


def build_sketch(keys, weights, active, k: int, capacity: int,
                 seed: int = 0) -> Sketch:
    """Compute S^(M,k) over a batch and compact S ∪ Z into a Sketch."""
    s = universal_monotone_sample(keys, weights, active, k, seed=seed)
    return _compact(keys, weights, s, k, capacity, seed)


def _compact(keys, weights, s: UniversalSample, k: int, capacity: int,
             seed: int) -> Sketch:
    keep = s.member | s.aux
    # order: kept first (members before aux), then by weight desc
    order = jnp.lexsort((-jnp.asarray(weights, jnp.float32), ~s.member, ~keep))
    n = order.shape[0]
    if n < capacity:  # pad so every sketch carries exactly `capacity` slots
        order = jnp.concatenate([order, jnp.zeros(capacity - n, order.dtype)])
        pad_valid = jnp.arange(capacity) < n
    else:
        order = order[:capacity]
        pad_valid = jnp.ones((capacity,), bool)
    take = order
    kk = jnp.asarray(keys, jnp.int32)[take]
    keep_t = keep[take] & pad_valid
    return Sketch(
        keys=jnp.where(keep_t, kk, -1),
        weights=jnp.where(keep_t, jnp.asarray(weights, jnp.float32)[take],
                          0.0),
        probs=jnp.where(keep_t, s.prob[take], 0.0),
        member=s.member[take] & keep_t,
        valid=keep_t,
        k=k, seed=seed)


def merge_sketches(a: Sketch, b: Sketch) -> Sketch:
    """Merge two sketches (same k/seed): concat, dedup (keep max weight),
    re-select. Exact per paper §5.2."""
    assert a.k == b.k and a.seed == b.seed, "sketches must share k and hash seed"
    keys = jnp.concatenate([a.keys, b.keys])
    weights = jnp.concatenate([a.weights, b.weights])
    valid = jnp.concatenate([a.valid, b.valid])
    return _rebuild(keys, weights, valid, a.k, a.keys.shape[0], a.seed)


def merge_many(sketches_keys, sketches_weights, sketches_valid, k: int,
               capacity: int, seed: int) -> Sketch:
    """Merge a stacked batch of sketches [m, c] -> one sketch (tree-free,
    single re-selection). Used after all_gather over the mesh."""
    return _rebuild(sketches_keys.reshape(-1), sketches_weights.reshape(-1),
                    sketches_valid.reshape(-1), k, capacity, seed)


def _rebuild(keys, weights, valid, k: int, capacity: int, seed: int) -> Sketch:
    # dedup by key keeping max weight (paper: w_x = max over elements)
    order = jnp.lexsort((-weights, keys))
    sk, sw, sv = keys[order], weights[order], valid[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool), sk[1:] == sk[:-1]])
    act = sv & ~dup & (sk >= 0)
    s = universal_monotone_sample(sk, sw, act, k, seed=seed)
    return _compact(sk, sw, s, k, capacity, seed)


def sketch_estimate(sk, f, segment_fn=None) -> jnp.ndarray:
    """HT estimate of Q(f, H) from a sketch (``Sketch`` or ``MultiSketch`` —
    any record with member/weights/probs/keys fields).

    segment_fn: optional vectorized predicate over keys selecting the
    segment H (default: the whole data set).
    """
    member = sk.member
    if segment_fn is not None:
        member = member & jnp.asarray(segment_fn(sk.keys), bool)
    contrib = jnp.where(member,
                        f(sk.weights) / jnp.maximum(sk.probs, 1e-30), 0.0)
    return jnp.sum(contrib)
