"""Statistic functions f for segment f-statistics Q(f, H) = sum_{x in H} f(w_x).

The paper (Cohen 2015, §1) considers functions f >= 0 with f(0) = 0. We make
them first-class, hashable, jit-static objects so sampling routines can be
specialized per objective set F under ``jax.jit``.

Families implemented (paper §1 examples):
  count     f(w) = 1 for w > 0
  sum       f(w) = w
  thresh_T  f(w) = 1 for w >= T else 0
  cap_T     f(w) = min(T, w)
  moment_p  f(w) = w ** p
  linear combinations  f = sum_i a_i g_i  (closure, paper §4)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class StatFn:
    """A statistic function f(w). Frozen/hashable => usable as a jit-static arg.

    kind: one of {"count", "sum", "thresh", "cap", "moment", "combo"}.
    param: scalar parameter (T for thresh/cap, p for moment).
    terms: for kind == "combo", tuple of (coef, StatFn) pairs.
    """

    kind: str
    param: float = 0.0
    terms: Tuple[Tuple[float, "StatFn"], ...] = ()

    def __call__(self, w):
        w = jnp.asarray(w)
        if self.kind == "count":
            return (w > 0).astype(jnp.float32)
        if self.kind == "sum":
            return w.astype(jnp.float32)
        if self.kind == "thresh":
            return (w >= self.param).astype(jnp.float32)
        if self.kind == "cap":
            return jnp.minimum(w, self.param).astype(jnp.float32)
        if self.kind == "moment":
            # w**p with f(0) = 0 enforced (0**p is fine for p>0 but guard p<1
            # numerical paths).
            wf = w.astype(jnp.float32)
            return jnp.where(wf > 0, jnp.power(jnp.maximum(wf, 1e-30), self.param), 0.0)
        if self.kind == "combo":
            out = jnp.zeros(w.shape, jnp.float32)
            for coef, g in self.terms:
                out = out + jnp.float32(coef) * g(w)
            return out
        raise ValueError(f"unknown StatFn kind: {self.kind}")

    @property
    def name(self) -> str:
        if self.kind in ("count", "sum"):
            return self.kind
        if self.kind == "thresh":
            return f"thresh_{self.param:g}"
        if self.kind == "cap":
            return f"cap_{self.param:g}"
        if self.kind == "moment":
            return f"moment_{self.param:g}"
        return "combo(" + "+".join(f"{c:g}*{g.name}" for c, g in self.terms) + ")"

    def is_monotone(self) -> bool:
        """All the families above are monotone non-decreasing (paper §5 M)."""
        if self.kind == "combo":
            return all(c >= 0 and g.is_monotone() for c, g in self.terms)
        return True


COUNT = StatFn("count")
SUM = StatFn("sum")


def thresh(T: float) -> StatFn:
    return StatFn("thresh", float(T))


def cap(T: float) -> StatFn:
    return StatFn("cap", float(T))


def moment(p: float) -> StatFn:
    return StatFn("moment", float(p))


def combo(*terms: Tuple[float, StatFn]) -> StatFn:
    """Non-negative linear combination sum_i a_i g_i (paper Thm 4.1)."""
    for coef, _ in terms:
        if coef < 0:
            raise ValueError("closure (Thm 4.1) requires non-negative coefficients")
    return StatFn("combo", 0.0, tuple((float(c), g) for c, g in terms))


def disparity(f: StatFn, g: StatFn, w_grid) -> jnp.ndarray:
    """rho(f,g) = max_w f/g * max_w g/f over a weight grid (paper §2.4).

    Evaluated numerically on ``w_grid`` (w > 0); rho >= 1 with equality iff
    g = c f on the grid.
    """
    w = jnp.asarray(w_grid, jnp.float32)
    fv = f(w)
    gv = g(w)
    ok = (fv > 0) & (gv > 0)
    r1 = jnp.max(jnp.where(ok, fv / jnp.maximum(gv, 1e-30), 0.0))
    r2 = jnp.max(jnp.where(ok, gv / jnp.maximum(fv, 1e-30), 0.0))
    return r1 * r2
