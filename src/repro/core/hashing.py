"""Shared randomization u_x via counter-based hashing.

Coordinated samples (paper §1, §3) require that every objective — and every
shard of a distributed computation — sees the SAME u_x for key x. We therefore
derive u_x from a stateless integer hash of (key, seed), not from stateful
RNG. Any worker on any pod reproduces u_x without communication, which is what
makes sample composition (paper §2.5/§5.2) correct under `jax.lax` collectives.

We use a splitmix32-style finalizer in uint32 arithmetic (JAX-friendly: no
x64 requirement), two rounds keyed by the seed.
"""
from __future__ import annotations

import jax.numpy as jnp

_GOLDEN = jnp.uint32(0x9E3779B9)


def _mix(h):
    """fmix32 finalizer from MurmurHash3 — full avalanche on uint32."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_u32(keys, seed: int | jnp.ndarray = 0):
    """uint32 hash of integer keys, keyed by seed."""
    k = jnp.asarray(keys).astype(jnp.uint32)
    s = jnp.asarray(seed).astype(jnp.uint32)
    h = _mix(k + _GOLDEN + s)
    h = _mix(h ^ (s * jnp.uint32(0x85EBCA6B) + jnp.uint32(1)))
    return h


def uniform01(keys, seed: int | jnp.ndarray = 0):
    """u_x ~ U[0,1) from key hash — in (0, 1) exclusive of exact 0.

    24 high bits -> float32 mantissa-exact uniform; shifted by half-ulp so
    u > 0 strictly (r = -log1p(-u) and seeds r/f(w) stay finite/positive).
    """
    h = hash_u32(keys, seed)
    # take top 24 bits -> [0, 2^24), scale to (0,1)
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return u + jnp.float32(0.5 / (1 << 24))


def ppswor_rank(u):
    """r_x = -ln(1 - u_x): Exp(1) rank for ppswor (paper §2.2)."""
    return -jnp.log1p(-jnp.asarray(u, jnp.float32))


def rank_of(u, scheme: str):
    """r_x per bottom-k scheme: 'priority' -> u; 'ppswor' -> -ln(1-u)."""
    if scheme == "priority":
        return jnp.asarray(u, jnp.float32)
    if scheme == "ppswor":
        return ppswor_rank(u)
    raise ValueError(f"unknown scheme {scheme!r} (want 'priority' or 'ppswor')")
