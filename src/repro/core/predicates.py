"""Segment-predicate wire format for batched segment queries Q^(f, H).

A segment H is any subset of the key space (paper §1: "segment
f-statistics"). The query engine evaluates B predicates x |F| objectives
over a MultiSketch slab in one kernel launch (kernels.segquery), so the
predicate must be a fixed-width DEVICE value, not a Python callable. The
wire format is one int32 row of ``PRED_COLS`` columns per predicate:

  col 0  lo     value-range lower bound (inclusive)
  col 1  hi     value-range upper bound (inclusive)
  col 2  mask   bitmask test: (v & mask) == want   (mask 0 -> always true)
  col 3  want
  col 4  salt   hash seed for ON_HASH predicates
  col 5  flags  bit 0 (ON_HASH): test v = hash31(key, salt) instead of the
                key itself

with v = key for plain predicates, or v = hash31(key, salt) = the top 31
bits of ``hash_u32(key, salt)`` (a uniform value in [0, 2^31)) when
ON_HASH is set. All three tests AND together, plus key >= 0 (slot
occupied). The same row therefore expresses:

  * key ranges        (lo, hi)           — e.g. "keys from steps >= 6"
  * key bitmasks      (mask, want)       — e.g. "domain id in low bits"
  * hashed fractions  ON_HASH + [0, q*2^31) — a coordinated uniform
    q-fraction of the key space, reproducible on every shard (same
    hash), as in the distance-oracle pattern of arXiv:1203.4903.

``predicate_matrix`` is the vectorized oracle shared by the XLA estimate
path and the kernel tests; the Pallas kernel (kernels.segquery) computes
the identical function in-VMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Union

import jax.numpy as jnp
import numpy as np

from .hashing import hash_u32

PRED_COLS = 6
FLAG_ON_HASH = 1
INT32_MIN = -(2 ** 31)
INT32_MAX = 2 ** 31 - 1
_HASH31_SPAN = 2 ** 31  # hash31 values are uniform in [0, 2^31)


@dataclasses.dataclass(frozen=True)
class SegmentPredicate:
    """One segment predicate H (hashable -> usable as a jit-static arg).

    Matches keys x with ``lo <= v <= hi`` and ``(v & mask) == want`` where
    v is the key itself, or hash31(key, salt) when ``on_hash``.
    """

    lo: int = INT32_MIN
    hi: int = INT32_MAX
    mask: int = 0
    want: int = 0
    salt: int = 0
    on_hash: bool = False

    def row(self) -> np.ndarray:
        """The predicate's int32 wire row [PRED_COLS]."""
        return np.array([self.lo, self.hi, self.mask, self.want, self.salt,
                         FLAG_ON_HASH if self.on_hash else 0], np.int32)

    def __call__(self, keys) -> jnp.ndarray:
        """Vectorized key predicate (drop-in ``segment_fn``)."""
        return predicate_matrix(keys, self.row()[None, :])[0]


EVERYTHING = SegmentPredicate()


def key_range(lo: int, hi: int) -> SegmentPredicate:
    """Keys in [lo, hi] inclusive."""
    return SegmentPredicate(lo=int(lo), hi=int(hi))


def key_mask(mask: int, want: int) -> SegmentPredicate:
    """Keys with (key & mask) == want (e.g. a domain id packed in key bits)."""
    return SegmentPredicate(mask=int(mask), want=int(want))


def hash_fraction(q: float, salt: int = 0) -> SegmentPredicate:
    """A coordinated uniform q-fraction of the key space: keys whose 31-bit
    hash (keyed by ``salt``) falls below q * 2^31. The same (q, salt) selects
    the same keys on every shard/host — shared hashing, paper §1."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"fraction q={q} outside [0, 1]")
    return SegmentPredicate(lo=0, hi=int(q * _HASH31_SPAN) - 1, salt=int(salt),
                            on_hash=True)


Predicates = Union[SegmentPredicate, Sequence[SegmentPredicate], np.ndarray,
                   jnp.ndarray]


def encode_predicates(preds: Predicates) -> np.ndarray:
    """-> int32 wire table [B, PRED_COLS]. Accepts a single predicate, a
    sequence of predicates, or an already-encoded table (passed through)."""
    if isinstance(preds, SegmentPredicate):
        return preds.row()[None, :]
    if isinstance(preds, (np.ndarray, jnp.ndarray)):
        t = np.asarray(preds, np.int32)
        if t.ndim != 2 or t.shape[1] != PRED_COLS:
            raise ValueError(
                f"predicate table must be [B, {PRED_COLS}], got {t.shape}")
        return t
    rows = [p.row() for p in preds]
    if not rows:
        raise ValueError("empty predicate batch")
    return np.stack(rows)


def never_row() -> np.ndarray:
    """A row matching nothing (lo > hi) — the padding element for batch
    quantization; padded query slots estimate exactly 0."""
    return np.array([1, 0, 0, 0, 0, 0], np.int32)


def pad_table(table: np.ndarray, b_pad: int) -> np.ndarray:
    """Pad a wire table to ``b_pad`` rows with never-matching predicates."""
    b = table.shape[0]
    if b >= b_pad:
        return table
    return np.concatenate([table, np.tile(never_row(), (b_pad - b, 1))])


def hash31(keys, salt) -> jnp.ndarray:
    """Top 31 bits of hash_u32(key, salt) as int32 in [0, 2^31) — the value
    ON_HASH predicates test. Broadcasts keys against salt."""
    return (hash_u32(keys, salt) >> jnp.uint32(1)).astype(jnp.int32)


def predicate_matrix(keys, table) -> jnp.ndarray:
    """Evaluate a wire table against keys: [B, PRED_COLS] x [n] -> bool [B, n].

    The reference implementation of the wire semantics; the segquery kernel
    computes the same function in-VMEM (bit-identical selection).
    """
    k = jnp.asarray(keys, jnp.int32)[None, :]                 # [1, n]
    t = jnp.asarray(table, jnp.int32)
    lo, hi = t[:, 0:1], t[:, 1:2]                             # [B, 1]
    mask, want = t[:, 2:3], t[:, 3:4]
    salt, flags = t[:, 4:5], t[:, 5:6]
    hv = hash31(k, salt)                                      # [B, n]
    v = jnp.where((flags & FLAG_ON_HASH) != 0, hv, k)
    return ((v >= lo) & (v <= hi) & ((v & mask) == want) & (k >= 0))
