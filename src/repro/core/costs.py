"""Service-cost objective wire format for the metric/clustering domain.

The paper's second application domain (§7) indexes objectives by METRIC
queries instead of key predicates: for a candidate center set C and
exponent mu, the service cost of a point x is

    f_C(x)     = min_{c in C} d(x, c)^mu          (k-median mu=1, k-means mu=2)
    f_{C,r}(x) = 1[min_{c in C} d(x, c) <= r]     (ball density / coverage)

and Sum(f_C; X) is the clustering cost of C (resp. the number of points C
covers within radius r). A candidate center set is RUNTIME data — the
optimizer proposes thousands of them — so unlike ``core.predicates`` the
wire format is a pytree of arrays, not a static row encoding:

  centers float32 [Q, Cmax, dim]  candidate sets, zero-padded to Cmax
  cvalid  bool    [Q, Cmax]       slot c of set q holds a real center
  mu      float32 [Q]             distance exponent (cost mode, mu > 0)
  param   float32 [Q]             radius r (ball mode)
  mode    int32   [Q]             MODE_COST | MODE_BALL

A row whose ``cvalid`` is all-False estimates exactly 0 in both modes —
the padding element for Q-bucket quantization (``pad_cost_table``).

``service_cost_values`` is the vectorized oracle shared by the XLA
estimate path and the kernel tests; the fused Pallas kernel
(kernels.servicecost) computes the same function in-VMEM with Q x Cmax
centers on sublanes and slab slots on lanes. Distances use the shared
quadratic expansion  d2(x,c) = |x|^2 + |c|^2 - 2 x.c  clamped at 0, so
both paths agree to float tolerance.

HT estimation (paper Eq. 2/5): Q(f_C, X) is estimated from a sampled slab
(MultiSketch or MetricSample — member/probs fields) as
sum_{x in S} f_C(x) / p_x, routed through ``core.estimators.estimate_many``
with the real-valued matrix ``service_cost_values`` standing in for the
boolean segment matrix.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

MODE_COST = 0
MODE_BALL = 1


class CostTable(NamedTuple):
    """Array wire format for a batch of Q service-cost queries."""

    centers: jnp.ndarray  # float32 [Q, Cmax, dim]
    cvalid: jnp.ndarray   # bool    [Q, Cmax]
    mu: jnp.ndarray       # float32 [Q]
    param: jnp.ndarray    # float32 [Q]
    mode: jnp.ndarray     # int32   [Q]


@dataclasses.dataclass(frozen=True, eq=False)
class ServiceCostQuery:
    """One service-cost query: a center set + mode parameters."""

    centers: np.ndarray   # [m, dim]
    mu: float = 1.0
    mode: int = MODE_COST
    radius: float = 0.0


def cost_query(centers, mu: float = 1.0) -> ServiceCostQuery:
    """Clustering-cost query: Sum over x of min_c d(x, c)^mu."""
    c = np.atleast_2d(np.asarray(centers, np.float32))
    return ServiceCostQuery(centers=c, mu=float(mu))


def ball_query(centers, radius: float) -> ServiceCostQuery:
    """Ball-density query: # points within ``radius`` of the set (a single
    center gives the classic ball |B(q, r)|)."""
    c = np.atleast_2d(np.asarray(centers, np.float32))
    return ServiceCostQuery(centers=c, mode=MODE_BALL, radius=float(radius))


CostQueries = Union[ServiceCostQuery, Sequence[ServiceCostQuery], CostTable]


def encode_cost_queries(queries: CostQueries, cmax: Optional[int] = None
                        ) -> CostTable:
    """-> CostTable padded to a common Cmax. Accepts a single query, a
    sequence (ragged set sizes fine), or an already-encoded table."""
    if isinstance(queries, CostTable):
        return queries
    if isinstance(queries, ServiceCostQuery):
        queries = [queries]
    qs = list(queries)
    if not qs:
        raise ValueError("empty service-cost query batch")
    dims = {q.centers.shape[1] for q in qs}
    if len(dims) != 1:
        raise ValueError(f"mixed center dims {sorted(dims)} in one batch")
    dim = dims.pop()
    need = max(q.centers.shape[0] for q in qs)
    cm = need if cmax is None else int(cmax)
    if cm < need:
        raise ValueError(f"cmax={cm} < largest set size {need}")
    qn = len(qs)
    centers = np.zeros((qn, cm, dim), np.float32)
    cvalid = np.zeros((qn, cm), bool)
    mu = np.zeros((qn,), np.float32)
    param = np.zeros((qn,), np.float32)
    mode = np.zeros((qn,), np.int32)
    for i, q in enumerate(qs):
        m = q.centers.shape[0]
        centers[i, :m] = np.asarray(q.centers, np.float32)
        cvalid[i, :m] = True
        mu[i] = q.mu
        param[i] = q.radius
        mode[i] = q.mode
    return CostTable(centers=centers, cvalid=cvalid, mu=mu, param=param,
                     mode=mode)


def cost_table(center_sets, mu: float = 1.0) -> CostTable:
    """Encode a batch of center sets (sequence of [m_i, dim] arrays, or one
    [Q, m, dim] tensor) as cost-mode queries sharing one mu."""
    sets = (list(center_sets) if not hasattr(center_sets, "shape")
            else [center_sets[i] for i in range(center_sets.shape[0])])
    return encode_cost_queries([cost_query(c, mu) for c in sets])


def pad_cost_table(table: CostTable, q_pad: int) -> CostTable:
    """Pad to ``q_pad`` rows with null queries (no valid centers -> estimate
    exactly 0) so same-bucket batches share one compiled executable."""
    q = table.mu.shape[0]
    if q >= q_pad:
        return table
    pad = q_pad - q
    return CostTable(
        centers=np.concatenate(
            [np.asarray(table.centers, np.float32),
             np.zeros((pad,) + tuple(np.shape(table.centers)[1:]),
                      np.float32)]),
        cvalid=np.concatenate([np.asarray(table.cvalid, bool),
                               np.zeros((pad, np.shape(table.cvalid)[1]),
                                        bool)]),
        mu=np.concatenate([np.asarray(table.mu, np.float32),
                           np.zeros((pad,), np.float32)]),
        param=np.concatenate([np.asarray(table.param, np.float32),
                              np.zeros((pad,), np.float32)]),
        mode=np.concatenate([np.asarray(table.mode, np.int32),
                             np.zeros((pad,), np.int32)]))


def sq_dists(centers, points) -> jnp.ndarray:
    """Squared distances [m, c] via the shared quadratic expansion — the ONE
    distance formula of both the XLA oracle and the Pallas kernel."""
    ctr = jnp.asarray(centers, jnp.float32)
    pts = jnp.asarray(points, jnp.float32)
    dots = jax.lax.dot_general(ctr, pts, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    cn2 = jnp.sum(ctr * ctr, axis=1)
    pn2 = jnp.sum(pts * pts, axis=1)
    return jnp.maximum(cn2[:, None] + pn2[None, :] - 2.0 * dots, 0.0)


def service_cost_values(points, table: CostTable) -> jnp.ndarray:
    """Evaluate a cost table against points: [Q, Cmax, dim] x [c, dim]
    -> float32 [Q, c] of f-values (min-dist^mu, or the ball indicator).

    The reference implementation of the wire semantics; the servicecost
    kernel computes the same function in-VMEM.
    """
    pts = jnp.asarray(points, jnp.float32)
    ctr = jnp.asarray(table.centers, jnp.float32)
    qn, cm, dim = ctr.shape
    d2 = sq_dists(ctr.reshape(qn * cm, dim), pts)            # [Q*Cmax, c]
    d2 = jnp.where(jnp.asarray(table.cvalid, bool).reshape(-1)[:, None],
                   d2, jnp.float32(jnp.inf))
    mind2 = jnp.min(d2.reshape(qn, cm, -1), axis=1)          # [Q, c]
    finite = jnp.isfinite(mind2)
    mu = jnp.asarray(table.mu, jnp.float32)[:, None]
    r = jnp.asarray(table.param, jnp.float32)[:, None]
    cost = jnp.where(mind2 > 0,
                     jnp.power(jnp.maximum(mind2, 1e-38), 0.5 * mu), 0.0)
    ball = (mind2 <= r * r).astype(jnp.float32)
    out = jnp.where(jnp.asarray(table.mode, jnp.int32)[:, None] == MODE_BALL,
                    ball, cost)
    return jnp.where(finite, out, 0.0)


def estimate_service_costs(points, probs, member, queries: CostQueries,
                           point_weights=None,
                           use_kernels: Optional[bool] = None,
                           interpret=None) -> jnp.ndarray:
    """Batched HT estimates of Q clustering costs / ball densities -> [Q].

    points/probs/member: the sampled slab (coords [c, dim] aligned with the
    MultiSketch probs/member fields, or a MetricSample restriction);
    queries: ServiceCostQuery batch or encoded CostTable. The kernel path
    (default) is ONE fused Pallas launch for the whole Q x Cmax batch;
    use_kernels=False takes the bit-compatible XLA path (the shared oracle
    matrix + one estimate_many matmul). ``point_weights``: optional per-slot
    data weights (multiplicities) for weighted point sets.
    """
    table = encode_cost_queries(queries)
    uk = True if use_kernels is None else use_kernels
    if uk:
        from repro.kernels.servicecost import service_cost_slab
        return service_cost_slab(points, probs, member, table,
                                 point_weights=point_weights,
                                 interpret=interpret)
    return _estimate_xla_jit(
        jnp.asarray(points, jnp.float32), jnp.asarray(probs, jnp.float32),
        jnp.asarray(member, bool),
        CostTable(*(jnp.asarray(x) for x in table)),
        point_weights if point_weights is None
        else jnp.asarray(point_weights, jnp.float32))


@jax.jit
def _estimate_xla_jit(points, probs, member, table, point_weights):
    from .estimators import estimate_many
    from .funcs import SUM
    values = service_cost_values(points, table)               # [Q, c]
    pw = (jnp.ones(points.shape[:1], jnp.float32) if point_weights is None
          else point_weights)
    # SUM(pw) * ht is exactly the per-slot HT weight; the real-valued
    # f_C matrix rides the (float-cast) segment axis of estimate_many.
    return estimate_many((SUM,), pw, probs, member, values)[0]


def exact_service_costs(points, queries: CostQueries,
                        point_weights=None) -> jnp.ndarray:
    """Ground-truth costs over the FULL point set (validation / the exact
    scorer of launch.cluster): -> [Q]."""
    table = encode_cost_queries(queries)
    pts = jnp.asarray(points, jnp.float32)
    values = service_cost_values(pts, CostTable(*(jnp.asarray(x)
                                                  for x in table)))
    pw = (jnp.ones(pts.shape[:1], jnp.float32) if point_weights is None
          else jnp.asarray(point_weights, jnp.float32))
    return values @ pw
