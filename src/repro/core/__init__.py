"""Core library: multi-objective weighted sampling (Cohen 2015).

Public API re-exports for the paper's primary contribution (C1-C9, DESIGN.md).
"""
from .funcs import COUNT, SUM, StatFn, cap, combo, disparity, moment, thresh
from .hashing import hash_u32, ppswor_rank, rank_of, uniform01
from .pps import PpsSample, pps_probabilities, pps_sample
from .bottomk import BottomK, bottomk_sample, conditional_prob, f_seed
from .multi_objective import (MultiBottomK, MultiPps, multi_bottomk_sample,
                              multi_pps_sample)
from .universal import (UniversalSample, expected_size_bound,
                        universal_monotone_ref, universal_monotone_sample)
from .capping import (CappingSample, capping_size_bound, universal_capping_ref,
                      universal_capping_sample)
from .estimators import (cv_bound, estimate, estimate_many,
                         estimate_segments, exact, exact_segments)
from .merge import (Sketch, build_sketch, merge_many, merge_sketches,
                    sketch_capacity, sketch_estimate)
from .multi_sketch import (MultiSketch, MultiSketchSpec, multisketch_absorb,
                           multisketch_absorb_inline, multisketch_absorb_into,
                           multisketch_absorb_slabs, multisketch_build,
                           multisketch_empty, multisketch_estimate,
                           multisketch_finalize,
                           multisketch_estimate_batch, multisketch_merge,
                           multisketch_merge_stacked, multisketch_overflow,
                           multisketch_query_many, multisketch_select,
                           multisketch_slab_bytes, quarantine_chunk)
from .predicates import (EVERYTHING, SegmentPredicate, encode_predicates,
                         hash_fraction, key_mask, key_range,
                         predicate_matrix)
from .metric_domains import (MetricSample, MetricSketch,
                             estimate_ball_density, estimate_centrality,
                             farthest_point_anchors, metric_sample_sketch,
                             universal_metric_sample)
from .costs import (MODE_BALL, MODE_COST, CostTable, ServiceCostQuery,
                    ball_query, cost_query, cost_table, encode_cost_queries,
                    estimate_service_costs, exact_service_costs,
                    pad_cost_table, service_cost_values)

__all__ = [
    "StatFn", "COUNT", "SUM", "cap", "thresh", "moment", "combo", "disparity",
    "hash_u32", "uniform01", "ppswor_rank", "rank_of",
    "PpsSample", "pps_probabilities", "pps_sample",
    "BottomK", "bottomk_sample", "conditional_prob", "f_seed",
    "MultiPps", "MultiBottomK", "multi_pps_sample", "multi_bottomk_sample",
    "UniversalSample", "universal_monotone_ref", "universal_monotone_sample",
    "expected_size_bound",
    "CappingSample", "universal_capping_ref", "universal_capping_sample",
    "capping_size_bound",
    "estimate", "estimate_many", "estimate_segments", "exact",
    "exact_segments", "cv_bound",
    "Sketch", "build_sketch", "merge_sketches", "merge_many",
    "sketch_capacity", "sketch_estimate",
    "MultiSketch", "MultiSketchSpec", "multisketch_absorb",
    "multisketch_absorb_inline", "multisketch_absorb_into",
    "multisketch_absorb_slabs",
    "multisketch_build", "multisketch_empty", "multisketch_estimate",
    "multisketch_finalize",
    "multisketch_estimate_batch", "multisketch_query_many",
    "multisketch_merge", "multisketch_merge_stacked", "multisketch_overflow",
    "multisketch_select", "multisketch_slab_bytes", "quarantine_chunk",
    "SegmentPredicate", "EVERYTHING", "key_range", "key_mask",
    "hash_fraction", "encode_predicates", "predicate_matrix",
    "MetricSample", "MetricSketch", "universal_metric_sample",
    "metric_sample_sketch", "farthest_point_anchors", "estimate_centrality",
    "estimate_ball_density",
    "CostTable", "ServiceCostQuery", "MODE_COST", "MODE_BALL",
    "cost_query", "ball_query", "cost_table", "encode_cost_queries",
    "pad_cost_table", "service_cost_values", "estimate_service_costs",
    "exact_service_costs",
]
