"""Poisson probability-proportional-to-size (pps) sampling (paper §2.1).

Fixed-shape batch API: a data set is (keys, weights, active) arrays where
``active`` masks live entries (inactive slots behave as w_x = 0). All
functions are jit-compatible with k and f static.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .funcs import StatFn
from .hashing import uniform01


class PpsSample(NamedTuple):
    """pps sample: inclusion mask + per-key probs + the auxiliary total sum.

    The total ``fsum`` is the auxiliary information the paper (§2.3) attaches
    to the sample so inverse-probability weights can be recomputed downstream.
    """

    member: jnp.ndarray  # bool [n] — x in S
    prob: jnp.ndarray    # float32 [n] — p_x (0 for inactive keys)
    fsum: jnp.ndarray    # float32 [] — sum_x f(w_x)


def pps_probabilities(weights, active, f: StatFn, k: int):
    """p_x = min(1, k f(w_x) / sum_y f(w_y))   (paper Eq. 1)."""
    fv = jnp.where(active, f(weights), 0.0)
    fsum = jnp.sum(fv)
    p = jnp.minimum(1.0, k * fv / jnp.maximum(fsum, 1e-30))
    return jnp.where(active & (fv > 0), p, 0.0), fsum


def pps_sample(keys, weights, active, f: StatFn, k: int, seed=0) -> PpsSample:
    """Independent inclusion with probability p_x^(f,k).

    Uses the shared hash u_x (coordination across objectives, paper §3):
    x is included iff u_x < p_x. Coordinated pps samples for different f are
    nested exactly as the multi-objective construction (Eq. 4) requires.
    """
    p, fsum = pps_probabilities(weights, active, f, k)
    u = uniform01(keys, seed)
    return PpsSample(member=(u < p), prob=p, fsum=fsum)
