"""Model assembly for all architecture families.

Public API:
  init_model(key, cfg)            -> (params, specs)  (specs: logical axes)
  loss_fn(params, cfg, batch)     -> (loss, metrics)  (training forward)
  make_cache(cfg, batch, max_len) -> decode cache pytree
  serve_step(params, cfg, tokens, cache, index) -> (logits, new_cache)

Layer stacks are scanned (stacked params, leading "layers" axis) with
optional per-layer remat — compile time and HLO size stay O(1) in depth.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba as M
from . import moe as MOE
from .config import ModelConfig

ACT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(key, n, init_one):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_one(k)[0])(keys)
    _, spec = init_one(key)  # spec tree (leaves = tuples of logical axes)
    # prepend the (scanned, unsharded) layers axis to every leaf spec
    spec = jax.tree.map(lambda s: (None,) + tuple(s), spec,
                        is_leaf=lambda s: isinstance(s, tuple))
    return params, spec


def _init_layer(key, cfg: ModelConfig):
    """One decoder layer of the cfg's family (params, specs)."""
    p, s = {}, {}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cfg.family in ("dense", "moe", "encoder", "vlm"):
        p["ln1"], s["ln1"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        p["attn"], s["attn"] = L.init_attention(k1, cfg)
        p["ln2"], s["ln2"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        if cfg.family == "moe":
            p["moe"], s["moe"] = MOE.init_moe(k2, cfg)
        else:
            p["mlp"], s["mlp"] = L.init_mlp(k2, cfg)
    elif cfg.family == "ssm":
        p["ln1"], s["ln1"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        p["mamba"], s["mamba"] = M.init_mamba1(k1, cfg)
    elif cfg.family == "hybrid":
        p["ln1"], s["ln1"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        p["mamba"], s["mamba"] = M.init_mamba2(k1, cfg)
    else:
        raise ValueError(cfg.family)
    return p, s


def init_model(key, cfg: ModelConfig):
    kemb, klay, kshared, khead = jax.random.split(key, 4)
    p, s = {}, {}
    p["emb"], s["emb"] = L.init_embedding(kemb, cfg)
    p["layers"], s["layers"] = _stack_init(klay, cfg.num_layers,
                                           partial(_init_layer, cfg=cfg))
    p["ln_f"], s["ln_f"] = L.init_norm(cfg.norm_kind, cfg.d_model)
    if cfg.family == "hybrid":
        sp, ss = {}, {}
        sp["ln1"], ss["ln1"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        sp["attn"], ss["attn"] = L.init_attention(kshared, cfg)
        sp["ln2"], ss["ln2"] = L.init_norm(cfg.norm_kind, cfg.d_model)
        sp["mlp"], ss["mlp"] = L.init_mlp(khead, cfg)
        p["shared"], s["shared"] = sp, ss
    return p, s


# ---------------------------------------------------------------------------
# forward (training) — full-sequence
# ---------------------------------------------------------------------------

def _transformer_layer(lp, x, cfg, positions):
    x = L.shard_tokens(x, cfg.constrain_acts)
    h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    a, _ = L.apply_attention(lp["attn"], h, cfg, positions)
    x = L.shard_tokens(x + a, cfg.constrain_acts)
    h = L.apply_norm(lp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    if "moe" in lp:
        m, aux = MOE.apply_moe(lp["moe"], h, cfg)
    else:
        m, aux = L.apply_mlp(lp["mlp"], h, cfg), {}
    return L.shard_tokens(x + m, cfg.constrain_acts), aux


def _ssm_layer(lp, x, cfg):
    h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    kind = cfg.ssm_kind
    if kind == "mamba1":
        y, _ = M.apply_mamba1(lp["mamba"], h, cfg)
    else:
        y, _ = M.apply_mamba2(lp["mamba"], h, cfg)
    return x + y


def _run_stack(params, cfg, x, positions):
    """Scan layers; returns (hidden, aux_losses)."""
    zero_aux = {"moe_aux": jnp.float32(0), "moe_z": jnp.float32(0)}

    if cfg.family in ("dense", "moe", "encoder", "vlm"):
        def body(carry, lp):
            x, aux = carry
            x2, a = _transformer_layer(lp, x, cfg, positions)
            aux = {k: aux[k] + a.get(k, 0.0) for k in aux}
            return (x2, aux), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, zero_aux), params["layers"])
        return x, aux

    if cfg.family == "ssm":
        def body(x, lp):
            return _ssm_layer(lp, x, cfg), None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, zero_aux

    # hybrid: groups of attn_every mamba2 layers + shared attn/mlp block
    n_groups = cfg.num_layers // cfg.attn_every
    assert n_groups * cfg.attn_every == cfg.num_layers
    grouped = jax.tree.map(
        lambda t: t.reshape(n_groups, cfg.attn_every, *t.shape[1:]),
        params["layers"])
    shared = params["shared"]

    def inner(x, lp):
        return _ssm_layer(lp, x, cfg), None

    def group_body(x, gp):
        x, _ = jax.lax.scan(inner, x, gp)
        h = L.apply_norm(shared["ln1"], x, cfg.norm_kind, cfg.norm_eps)
        a, _ = L.apply_attention(shared["attn"], h, cfg, positions)
        x = x + a
        h = L.apply_norm(shared["ln2"], x, cfg.norm_kind, cfg.norm_eps)
        x = x + L.apply_mlp(shared["mlp"], h, cfg)
        return x, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    x, _ = jax.lax.scan(group_body, x, grouped)
    return x, zero_aux


def _inputs_to_hidden(params, cfg, batch):
    """Embed per-family inputs -> (hidden [B,S,D], positions, labels, mask)."""
    if cfg.family == "encoder":
        x = batch["frames"].astype(ACT_DTYPE)           # [B,S,D] stub frontend
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        return x, positions, batch["labels"], jnp.ones((B, S), bool)
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    x = L.embed_tokens(params["emb"], tokens, ACT_DTYPE)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(ACT_DTYPE)    # [B,P,D] stub frontend
        x = jnp.concatenate([patches, x], axis=1)
        P = patches.shape[1]
        S = S_tok + P
        text_mask = jnp.concatenate(
            [jnp.zeros((B, P), bool), jnp.ones((B, S_tok), bool)], axis=1)
    else:
        S = S_tok
        text_mask = jnp.ones((B, S), bool)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    # next-token labels over the combined sequence
    pad = jnp.zeros((B, 1), tokens.dtype)
    full_tokens = (jnp.concatenate([jnp.zeros((B, S - S_tok), tokens.dtype),
                                    tokens], axis=1)
                   if S != S_tok else tokens)
    labels = jnp.concatenate([full_tokens[:, 1:], pad], axis=1)
    mask = text_mask & (jnp.arange(S) < S - 1)[None, :]
    if "loss_mask" in batch and cfg.family != "vlm":
        mask = mask & batch["loss_mask"].astype(bool)
    return x, positions, labels, mask


def forward_logits(params, cfg: ModelConfig, batch):
    """Full-sequence logits [B, S, V] — small models / tests only."""
    x, positions, _, _ = _inputs_to_hidden(params, cfg, batch)
    x, _ = _run_stack(params, cfg, x, positions)
    x = L.apply_norm(params["ln_f"], x, cfg.norm_kind, cfg.norm_eps)
    W = L.unembed_matrix(params["emb"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        W.astype(jnp.float32))
    if cfg.vocab_padded > cfg.vocab_size:
        logits = logits + (jnp.arange(cfg.vocab_padded)
                           >= cfg.vocab_size) * -1e30
    return logits


def loss_fn(params, cfg: ModelConfig, batch):
    x, positions, labels, mask = _inputs_to_hidden(params, cfg, batch)
    x, aux = _run_stack(params, cfg, x, positions)
    x = L.apply_norm(params["ln_f"], x, cfg.norm_kind, cfg.norm_eps)
    ce = L.chunked_ce_loss(params["emb"], x, labels, mask, cfg.loss_chunk,
                           vocab_size=cfg.vocab_size)
    loss = ce + 0.01 * aux["moe_aux"] + 0.001 * aux["moe_z"]
    return loss, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=ACT_DTYPE):
    Lr = cfg.num_layers
    if cfg.family in ("dense", "moe", "vlm"):
        kv = lambda: jnp.zeros((Lr, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype)
        return {"k": kv(), "v": kv()}
    if cfg.family == "ssm":
        st = M.mamba1_state(cfg, batch, dtype)
        return jax.tree.map(
            lambda t: jnp.zeros((Lr, *t.shape), t.dtype), st)
    if cfg.family == "hybrid":
        st = M.mamba2_state(cfg, batch, dtype)
        n_groups = cfg.num_layers // cfg.attn_every
        return {
            "mamba": jax.tree.map(
                lambda t: jnp.zeros((Lr, *t.shape), t.dtype), st),
            "k": jnp.zeros((n_groups, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((n_groups, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
        }
    raise ValueError(f"{cfg.family} has no decode step")


def grow_cache(cfg: ModelConfig, cache, extra: int):
    """Extend a prefill cache's time axis by ``extra`` decode slots.

    Attention k/v leaves (dense/moe/vlm and the hybrid family's attention
    groups) have layout [groups, B, T, H, D]; SSM state leaves carry no time
    axis and pass through unchanged.
    """
    if extra <= 0 or not isinstance(cache, dict):
        return cache
    grown = dict(cache)
    for name in ("k", "v"):
        if name in grown:
            pad = [(0, 0)] * grown[name].ndim
            pad[2] = (0, extra)
            grown[name] = jnp.pad(grown[name], pad)
    return grown


def serve_step(params, cfg: ModelConfig, tokens, cache, index):
    """One decode step. tokens: [B] int32; index: current length (scalar).

    Returns (logits [B, vocab], new_cache).
    """
    B = tokens.shape[0]
    x = L.embed_tokens(params["emb"], tokens[:, None], ACT_DTYPE)  # [B,1,D]
    positions = jnp.full((B, 1), index, jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, xs):
            lp, ck, cv = xs
            h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            a, nc = L.apply_attention(lp["attn"], h, cfg, positions,
                                      cache={"k": ck, "v": cv},
                                      cache_index=index)
            x = x + a
            h = L.apply_norm(lp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            if "moe" in lp:
                m, _ = MOE.apply_moe(lp["moe"], h, cfg)
            else:
                m = L.apply_mlp(lp["mlp"], h, cfg)
            return x + m, (nc["k"], nc["v"])

        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}

    elif cfg.family == "ssm":
        def body(x, xs):
            lp, st = xs
            h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            y, ns = M.apply_mamba1(lp["mamba"], h, cfg, state=st)
            return x + y, ns

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    else:  # hybrid
        n_groups = cfg.num_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda t: t.reshape(n_groups, cfg.attn_every, *t.shape[1:]),
            params["layers"])
        gstates = jax.tree.map(
            lambda t: t.reshape(n_groups, cfg.attn_every, *t.shape[1:]),
            cache["mamba"])
        shared = params["shared"]

        def inner(x, xs):
            lp, st = xs
            h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            y, ns = M.apply_mamba2(lp["mamba"], h, cfg, state=st)
            return x + y, ns

        def group_body(x, xs):
            gp, gst, ck, cv = xs
            x, nst = jax.lax.scan(inner, x, (gp, gst))
            h = L.apply_norm(shared["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            a, nc = L.apply_attention(shared["attn"], h, cfg, positions,
                                      cache={"k": ck, "v": cv},
                                      cache_index=index)
            x = x + a
            h = L.apply_norm(shared["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + L.apply_mlp(shared["mlp"], h, cfg)
            return x, (nst, nc["k"], nc["v"])

        x, (nmamba, nk, nv) = jax.lax.scan(
            group_body, x, (grouped, gstates, cache["k"], cache["v"]))
        new_cache = {
            "mamba": jax.tree.map(
                lambda t: t.reshape(cfg.num_layers, *t.shape[2:]), nmamba),
            "k": nk, "v": nv}

    x = L.apply_norm(params["ln_f"], x, cfg.norm_kind, cfg.norm_eps)
    logits = L.logits_last(params["emb"], x[:, 0], cfg.vocab_size)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also emits the decode cache
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch):
    """Forward the prompt and build the decode cache (inference prefill).

    Returns (logits [B, Vp] for the last position, cache compatible with
    serve_step at max_len = S).
    """
    x, positions, _, _ = _inputs_to_hidden(params, cfg, batch)
    B, S, _ = x.shape

    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        def body(x, lp):
            x = L.shard_tokens(x, cfg.constrain_acts)
            h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            dt = x.dtype
            H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            q = (h @ lp["attn"]["wq"].astype(dt)).reshape(B, S, H, hd)
            k = (h @ lp["attn"]["wk"].astype(dt)).reshape(B, S, K, hd)
            v = (h @ lp["attn"]["wv"].astype(dt)).reshape(B, S, K, hd)
            if cfg.qkv_bias:
                q = q + lp["attn"]["bq"].astype(dt).reshape(1, 1, H, hd)
                k = k + lp["attn"]["bk"].astype(dt).reshape(1, 1, K, hd)
                v = v + lp["attn"]["bv"].astype(dt).reshape(1, 1, K, hd)
            q = L.shard_heads(L.rope(q, positions, cfg.rope_theta),
                              cfg.constrain_acts)
            k = L.shard_heads(L.rope(k, positions, cfg.rope_theta),
                              cfg.constrain_acts)
            v = L.shard_heads(v, cfg.constrain_acts)
            a = L.chunked_attention(q, k, v, causal=cfg.causal,
                                    chunk=cfg.attn_chunk)
            x = L.shard_tokens(
                x + a.reshape(B, S, H * hd) @ lp["attn"]["wo"].astype(dt),
                cfg.constrain_acts)
            h = L.apply_norm(lp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            if "moe" in lp:
                m, _ = MOE.apply_moe(lp["moe"], h, cfg)
            else:
                m = L.apply_mlp(lp["mlp"], h, cfg)
            return (L.shard_tokens(x + m, cfg.constrain_acts),
                    (k.astype(ACT_DTYPE), v.astype(ACT_DTYPE)))

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        # encoders have no decode step: the "prefill" cell is the plain
        # inference forward; no cache is produced
        cache = {} if cfg.family == "encoder" else {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body(x, lp):
            h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            y, st = M.apply_mamba1(lp["mamba"], h, cfg, return_state=True)
            return x + y, st
        if cfg.remat:
            body = jax.checkpoint(body)
        x, states = jax.lax.scan(body, x, params["layers"])
        cache = states

    else:  # hybrid
        n_groups = cfg.num_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda t: t.reshape(n_groups, cfg.attn_every, *t.shape[1:]),
            params["layers"])
        shared = params["shared"]

        def inner(x, lp):
            h = L.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            y, st = M.apply_mamba2(lp["mamba"], h, cfg, return_state=True)
            return x + y, st

        def group_body(x, gp):
            x, sts = jax.lax.scan(inner, x, gp)
            h = L.apply_norm(shared["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            dt = x.dtype
            H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            q = (h @ shared["attn"]["wq"].astype(dt)).reshape(B, S, H, hd)
            k = (h @ shared["attn"]["wk"].astype(dt)).reshape(B, S, K, hd)
            v = (h @ shared["attn"]["wv"].astype(dt)).reshape(B, S, K, hd)
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            a = L.chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
            x = x + a.reshape(B, S, H * hd) @ shared["attn"]["wo"].astype(dt)
            h = L.apply_norm(shared["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + L.apply_mlp(shared["mlp"], h, cfg)
            return x, (sts, k.astype(ACT_DTYPE), v.astype(ACT_DTYPE))

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        x, (sts, ks, vs) = jax.lax.scan(group_body, x, grouped)
        cache = {
            "mamba": jax.tree.map(
                lambda t: t.reshape(cfg.num_layers, *t.shape[2:]), sts),
            "k": ks, "v": vs}

    x = L.apply_norm(params["ln_f"], x, cfg.norm_kind, cfg.norm_eps)
    logits = L.logits_last(params["emb"], x[:, -1], cfg.vocab_size)
    return logits, cache
