"""Selective state-space blocks: Mamba-1 (S6) and Mamba-2 (SSD).

TPU adaptation: the CUDA "hardware-aware" fused scan becomes a CHUNKED
sequence scan — `lax.scan` over chunks carrying the SSM state, with the
within-chunk recurrence computed by `lax.associative_scan` (mamba1) or the
quadratic SSD dual form (mamba2). States are never materialized for the whole
sequence, so 32k/500k contexts lower with O(S * d_inner) activation memory.

SPMD note: input projections are stored UNFUSED (separate z/x/B/C/dt
matrices) so each weight's output dim has a single semantic meaning and can
be sharded on the "inner" logical axis without splitting component
boundaries across shards.

Both blocks support single-token decode via an explicit recurrent state
(conv ring buffers + h), the sub-quadratic path used by `decode_32k` /
`long_500k`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _causal_conv(x, weight, bias):
    """Depthwise causal conv over seq. x: [B,S,C]; weight: [C,K]; bias: [C]."""
    K = weight.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), weight.T[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=weight.shape[0])
    return (out + bias).astype(x.dtype)


def _conv_step(state, xt, weight, bias):
    """One decode step of the causal conv. state: [B,K-1,C]; xt: [B,C]."""
    window = jnp.concatenate([state, xt[:, None, :]], axis=1)  # [B,K,C]
    out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                     weight.astype(jnp.float32)) + bias
    return window[:, 1:], out.astype(xt.dtype)


def _chunks(t, nC, Ck):
    B = t.shape[0]
    return jnp.moveaxis(t.reshape(B, nC, Ck, *t.shape[2:]), 1, 0)


# ---------------------------------------------------------------------------
# Mamba-1 (S6): per-channel diagonal A [d_inner, N]
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg):
    d, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    R = max(d // 16, 1)  # dt_rank
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["wz"], s["wz"] = dense_init(ks[0], d, di, ("embed", "inner"))
    p["wx"], s["wx"] = dense_init(ks[1], d, di, ("embed", "inner"))
    p["conv_w"] = 0.1 * jax.random.normal(ks[2], (di, K), jnp.float32)
    s["conv_w"] = ("inner", None)
    p["conv_b"] = jnp.zeros((di,), jnp.float32); s["conv_b"] = ("inner",)
    p["x_proj"], s["x_proj"] = dense_init(ks[3], di, R + 2 * N, ("inner", None))
    p["dt_proj"], s["dt_proj"] = dense_init(ks[4], R, di, (None, "inner"))
    u = jax.random.uniform(ks[5], (di,), jnp.float32,
                           math.log(1e-3), math.log(1e-1))
    p["dt_bias"] = jnp.log(jnp.expm1(jnp.exp(u))); s["dt_bias"] = ("inner",)
    p["A_log"] = jnp.log(jnp.broadcast_to(
        jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)).copy())
    s["A_log"] = ("inner", None)
    p["D"] = jnp.ones((di,), jnp.float32); s["D"] = ("inner",)
    p["out_proj"], s["out_proj"] = dense_init(ks[6], di, d, ("inner", "embed"))
    return p, s


def _m1_scan_chunk(h0, a, bx):
    """h_t = a_t h_{t-1} + bx_t via associative scan over the chunk axis.

    a, bx: [B, C, di, N]; h0: [B, di, N]. Returns (h_all, h_last).
    """
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    ca, cb = jax.lax.associative_scan(comb, (a, bx), axis=1)
    h_all = ca * h0[:, None] + cb
    return h_all, h_all[:, -1]


def apply_mamba1(p, x, cfg, state=None, return_state=False):
    """Full-seq (state=None) or single-step decode (state given).

    state: dict(conv=[B,K-1,di], h=[B,di,N]). Returns (y, new_state).
    return_state: full-seq prefill — also return the final recurrent state.
    """
    B, S, D = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    R = max(D // 16, 1)
    dt_ = x.dtype
    z = x @ p["wz"].astype(dt_)
    xs = x @ p["wx"].astype(dt_)
    A = -jnp.exp(p["A_log"])                                     # [di,N]

    if state is None:
        xc = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))
        proj = xc @ p["x_proj"].astype(dt_)
        dt_raw, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_proj"]
                             + p["dt_bias"])                     # [B,S,di]

        nC = max(S // cfg.ssm_chunk, 1)
        Ck = S // nC
        assert nC * Ck == S

        def chunk_step(h, xs_):
            @jax.checkpoint
            def inner(h, xc_, dt_c, B_c, C_c):
                a = jnp.exp(dt_c[..., None] * A)                 # [B,Ck,di,N]
                bx = (dt_c * xc_.astype(jnp.float32))[..., None] \
                    * B_c.astype(jnp.float32)[:, :, None, :]
                h_all, h_last = _m1_scan_chunk(h, a, bx)
                y = jnp.einsum("bcdn,bcn->bcd", h_all,
                               C_c.astype(jnp.float32))
                return h_last, y
            return inner(h, *xs_)

        h0 = jnp.zeros((B, di, N), jnp.float32)
        h_fin, ys = jax.lax.scan(
            chunk_step, h0, tuple(_chunks(t, nC, Ck) for t in (xc, dt, Bc, Cc)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
        y = (y + xc.astype(jnp.float32) * p["D"]).astype(dt_)
        new_state = None
        if return_state:
            K = cfg.ssm_conv
            new_state = {"conv": xs[:, S - (K - 1):].astype(dt_), "h": h_fin}
    else:
        xt = xs[:, 0]                                            # [B,di]
        conv_state, xc = _conv_step(state["conv"], xt, p["conv_w"], p["conv_b"])
        xc = jax.nn.silu(xc)
        proj = xc @ p["x_proj"].astype(dt_)
        dt_raw, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) @ p["dt_proj"]
                             + p["dt_bias"])                     # [B,di]
        a = jnp.exp(dt[..., None] * A)                           # [B,di,N]
        bx = (dt * xc.astype(jnp.float32))[..., None] \
            * Bc.astype(jnp.float32)[:, None, :]
        h = a * state["h"] + bx
        y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
        y = (y + xc.astype(jnp.float32) * p["D"]).astype(dt_)[:, None]
        new_state = {"conv": conv_state, "h": h}

    y = y * jax.nn.silu(z if state is None else z[:, :1])
    return y @ p["out_proj"].astype(dt_), new_state


def mamba1_state(cfg, batch: int, dtype=jnp.float32):
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD): scalar A per head, chunked dual form
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, K = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 9)
    p, s = {}, {}
    p["wz"], s["wz"] = dense_init(ks[0], d, di, ("embed", "inner"))
    p["wx"], s["wx"] = dense_init(ks[1], d, di, ("embed", "inner"))
    p["wB"], s["wB"] = dense_init(ks[2], d, N, ("embed", None))
    p["wC"], s["wC"] = dense_init(ks[3], d, N, ("embed", None))
    p["wdt"], s["wdt"] = dense_init(ks[4], d, H, ("embed", None))
    p["conv_x"] = 0.1 * jax.random.normal(ks[5], (di, K), jnp.float32)
    s["conv_x"] = ("inner", None)
    p["conv_xb"] = jnp.zeros((di,), jnp.float32); s["conv_xb"] = ("inner",)
    p["conv_B"] = 0.1 * jax.random.normal(ks[6], (N, K), jnp.float32)
    s["conv_B"] = (None, None)
    p["conv_Bb"] = jnp.zeros((N,), jnp.float32); s["conv_Bb"] = (None,)
    p["conv_C"] = 0.1 * jax.random.normal(ks[7], (N, K), jnp.float32)
    s["conv_C"] = (None, None)
    p["conv_Cb"] = jnp.zeros((N,), jnp.float32); s["conv_Cb"] = (None,)
    p["A_log"] = jnp.log(jax.random.uniform(ks[8], (H,), jnp.float32, 1.0, 16.0))
    s["A_log"] = (None,)
    u = jax.random.uniform(jax.random.fold_in(key, 99), (H,), jnp.float32,
                           math.log(1e-3), math.log(1e-1))
    p["dt_bias"] = jnp.log(jnp.expm1(jnp.exp(u))); s["dt_bias"] = (None,)
    p["D"] = jnp.ones((H,), jnp.float32); s["D"] = (None,)
    p["norm_scale"] = jnp.ones((di,), jnp.float32); s["norm_scale"] = ("inner",)
    p["out_proj"], s["out_proj"] = dense_init(
        jax.random.fold_in(key, 100), di, d, ("inner", "embed"))
    return p, s


def _m2_gated_out(p, y, z, cfg, dt_):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]
    return y.astype(dt_) @ p["out_proj"].astype(dt_)


def apply_mamba2(p, x, cfg, state=None, return_state=False):
    """SSD block. state: dict(conv_x, conv_B, conv_C, h=[B,H,hd,N])."""
    B, S, D = x.shape
    di, N, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype
    z = x @ p["wz"].astype(dt_)
    xs = x @ p["wx"].astype(dt_)
    Bp = x @ p["wB"].astype(dt_)
    Cp = x @ p["wC"].astype(dt_)
    dt_raw = x @ p["wdt"].astype(dt_)
    A = -jnp.exp(p["A_log"])                                     # [H]

    if state is None:
        xc = jax.nn.silu(_causal_conv(xs, p["conv_x"], p["conv_xb"]))
        Bc = jax.nn.silu(_causal_conv(Bp, p["conv_B"], p["conv_Bb"]))
        Cc = jax.nn.silu(_causal_conv(Cp, p["conv_C"], p["conv_Cb"]))
        xh = xc.reshape(B, S, H, hd)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
        loga = dt * A                                            # [B,S,H] (<0)

        nC = max(S // cfg.ssm_chunk, 1)
        Ck = S // nC
        assert nC * Ck == S

        def chunk_step(h, xs_):
            @jax.checkpoint
            def inner(h, xc, Bk, Ckk, dtc, la):
                cum = jnp.cumsum(la, axis=1)                     # [B,C,H]
                Bf, Cf = Bk.astype(jnp.float32), Ckk.astype(jnp.float32)
                xf = xc.astype(jnp.float32)
                sc = jnp.einsum("btn,bsn->bts", Cf, Bf)          # [B,C,C]
                dec = cum[:, :, None, :] - cum[:, None, :, :]    # [B,t,s,H]
                t_ = jnp.arange(xc.shape[1])
                causal = (t_[:, None] >= t_[None, :])[None, :, :, None]
                G = jnp.where(causal, jnp.exp(dec), 0.0) * sc[..., None]
                G = G * dtc[:, None, :, :]                       # dt_s weight
                y = jnp.einsum("btsh,bshd->bthd", G, xf)         # intra
                y = y + jnp.einsum("bth,btn,bhdn->bthd",
                                   jnp.exp(cum), Cf, h)          # inter
                w = jnp.exp(cum[:, -1:, :] - cum) * dtc          # [B,C,H]
                hb = jnp.einsum("bsh,bshd,bsn->bhdn", w, xf, Bf)
                h_out = jnp.exp(cum[:, -1])[:, :, None, None] * h + hb
                return h_out, y
            return inner(h, *xs_)

        h0 = jnp.zeros((B, H, hd, N), jnp.float32)
        h_fin, ys = jax.lax.scan(
            chunk_step, h0,
            tuple(_chunks(t, nC, Ck) for t in (xh, Bc, Cc, dt, loga)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)
        y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
        y = y.reshape(B, S, di)
        new_state = None
        if return_state:
            Kc = cfg.ssm_conv
            new_state = {"conv_x": xs[:, S - (Kc - 1):].astype(dt_),
                         "conv_B": Bp[:, S - (Kc - 1):].astype(dt_),
                         "conv_C": Cp[:, S - (Kc - 1):].astype(dt_),
                         "h": h_fin}
        return _m2_gated_out(p, y, z, cfg, dt_), new_state

    # ---- decode step ----
    cs_x, xc = _conv_step(state["conv_x"], xs[:, 0], p["conv_x"], p["conv_xb"])
    cs_B, Bc = _conv_step(state["conv_B"], Bp[:, 0], p["conv_B"], p["conv_Bb"])
    cs_C, Cc = _conv_step(state["conv_C"], Cp[:, 0], p["conv_C"], p["conv_Cb"])
    xc, Bc, Cc = jax.nn.silu(xc), jax.nn.silu(Bc), jax.nn.silu(Cc)
    xh = xc.reshape(B, H, hd)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = jnp.exp(dt * A)                                          # [B,H]
    hb = jnp.einsum("bh,bhd,bn->bhdn", dt, xh.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    h = a[:, :, None, None] * state["h"] + hb
    y = jnp.einsum("bn,bhdn->bhd", Cc.astype(jnp.float32), h)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di)
    new_state = {"conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C, "h": h}
    return _m2_gated_out(p, y, z[:, :1], cfg, dt_), new_state


def mamba2_state(cfg, batch: int, dtype=jnp.float32):
    K = cfg.ssm_conv
    return {"conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
            "conv_B": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
            "conv_C": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
            "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32)}
