"""Mixture-of-Experts block (granite-moe, qwen2-moe).

Dispatch is capacity-based scatter/gather (GShard-style semantics) WITHOUT the
[T, E, C] one-hot dispatch einsum: slot positions come from a per-row cumsum
of expert one-hots (local to each batch row, so no cross-device cumsum), and
tokens move via batched scatter/gather. Expert weights are sharded on the
"expert" logical axis (-> mesh "model"); the data->expert redistribution is
what surfaces as all-to-all / collective traffic in the dry-run HLO.

FLOPs are proportional to ACTIVE params (top-k + shared), matching the MoE
roofline convention MODEL_FLOPS = 6 * N_active * D.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import apply_mlp, dense_init, init_mlp


def moe_capacity(tokens_per_row: int, cfg) -> int:
    c = math.ceil(tokens_per_row * cfg.moe_top_k * cfg.capacity_factor
                  / cfg.num_experts)
    return max(8 * math.ceil(c / 8), 8)  # lane-aligned


def _n_experts(cfg) -> int:
    return max(cfg.num_experts_padded, cfg.num_experts)


def init_moe(key, cfg):
    ks = jax.random.split(key, 6)
    d, f, E = cfg.d_model, cfg.d_ff, _n_experts(cfg)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], d, E, ("embed", None))
    scale = 1.0 / math.sqrt(d)
    shape = (E, d, f)
    p["wi"] = scale * jax.random.truncated_normal(ks[1], -2, 2, shape, jnp.float32)
    p["wg"] = scale * jax.random.truncated_normal(ks[2], -2, 2, shape, jnp.float32)
    p["wo"] = (1.0 / math.sqrt(f)) * jax.random.truncated_normal(
        ks[3], -2, 2, (E, f, d), jnp.float32)
    s["wi"] = ("expert", "embed", "mlp")
    s["wg"] = ("expert", "embed", "mlp")
    s["wo"] = ("expert", "mlp", "embed")
    if cfg.num_shared_experts:
        p["shared"], s["shared"] = init_mlp(
            ks[4], cfg, d_ff=cfg.num_shared_experts * cfg.d_ff)
    return p, s


def apply_moe(p, x, cfg):
    """x: [B, S, D] -> ([B, S, D], aux_losses dict)."""
    B, S, D = x.shape
    E, k = _n_experts(cfg), cfg.moe_top_k
    C = moe_capacity(S, cfg)
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # [B,S,E]
    if E > cfg.num_experts:  # padded experts are masked out of routing
        pad_mask = (jnp.arange(E) >= cfg.num_experts) * -1e30
        logits = logits + pad_mask
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                        # [B,S,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing + z losses (Switch-style) ---
    me = jnp.mean(gates, axis=(0, 1))                           # [E]
    onehot_top = jax.nn.one_hot(topi, E, dtype=jnp.float32)     # [B,S,k,E]
    ce = jnp.mean(onehot_top.sum(2), axis=(0, 1))               # frac routed
    aux_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- slot assignment: per-row cumsum over the flattened (S*k) choices ---
    flat_e = topi.reshape(B, S * k)                             # [B, S*k]
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)             # [B, S*k, E]
    pos = jnp.cumsum(oh, axis=1) - 1                            # prior count
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]
    keep = slot < C
    dest = jnp.where(keep, flat_e * C + slot, E * C)            # OOB => dropped

    # --- scatter tokens to [B, E*C, D] expert buffers ---
    xk = jnp.repeat(x, k, axis=1)                               # [B, S*k, D]

    def scatter_row(dst_idx, vals):
        buf = jnp.zeros((E * C + 1, D), vals.dtype)
        return buf.at[dst_idx].add(vals, mode="drop")[:-1]

    expert_in = jax.vmap(scatter_row)(dest, xk)                 # [B, E*C, D]
    expert_in = expert_in.reshape(B, E, C, D)

    # --- expert FFN (swiglu), E sharded on "model" axis ---
    h = jnp.einsum("becd,edf->becf", expert_in, p["wi"].astype(dt))
    g = jnp.einsum("becd,edf->becf", expert_in, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    expert_out = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))
    expert_out = expert_out.reshape(B, E * C, D)

    # --- gather back + combine with (renormalized) gate weights ---
    def gather_row(buf, idx):
        return jnp.take(buf, idx, axis=0, mode="fill", fill_value=0)

    back = jax.vmap(gather_row)(expert_out, jnp.where(keep, dest, E * C))
    wts = (topv.reshape(B, S * k) * keep.astype(jnp.float32)).astype(dt)
    out = (back * wts[..., None]).reshape(B, S, k, D).sum(axis=2)

    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg)
    return out, {"moe_aux": aux_loss, "moe_z": z_loss}
