"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    mlp_kind: str = "swiglu"    # swiglu | geglu | gelu
    qkv_bias: bool = False
    causal: bool = True
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    num_experts_padded: int = 0  # pad expert dim so it shards evenly (the
                                 # router masks padded experts to -inf)
    # --- SSM (mamba1 / mamba2) ---
    ssm_kind: str = ""          # "" | mamba1 | mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64      # mamba2 heads = d_inner // ssm_head_dim
    ssm_chunk: int = 128        # scan chunk for train/prefill
    # --- hybrid (zamba2-style shared attention block) ---
    attn_every: int = 0         # apply the shared attn+mlp block every N layers
    # --- modality frontend (stubbed per spec) ---
    frontend: str = ""          # "" | "patch" (vlm) | "frames" (audio)
    frontend_tokens: int = 0    # patches/frames per example provided as embeds
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_chunk: int = 512       # kv-chunk for online-softmax attention
    loss_chunk: int = 1024      # seq-chunk for vocab-sharded CE loss
    remat: bool = True          # checkpoint each layer in the scan
    vocab_pad_multiple: int = 128  # pad embedding rows so vocab shards evenly
    fsdp: bool = False          # also shard params/opt over the "data" axis
                                # (ZeRO-3 via GSPMD; needed for >10B archs)
    constrain_acts: bool = False  # pin activations to (batch=data, seq/model
                                  # replicated) at layer boundaries — stops
                                  # XLA flip-flopping layouts (see §Perf B)

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_kind == "mamba2" else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6 N D)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = 0
        if self.family in ("dense", "moe", "encoder", "vlm") or self.attn_every:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        else:
            attn = 0
        if self.family == "moe":
            expert = 3 * d * self.d_ff
            mlp = self.num_experts * expert + self.num_shared_experts * expert
            mlp += d * self.num_experts  # router
        elif self.d_ff:
            mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            mlp = mult * d * self.d_ff
        else:
            mlp = 0
        if self.ssm_kind:
            di, N = self.d_inner, self.ssm_state
            ssm = 2 * d * di + di * d + di * self.ssm_conv
            if self.ssm_kind == "mamba1":
                ssm += di * N + 2 * di * N + di * (di // 16) * 2  # A, B/C proj, dt proj
            else:
                ssm += 2 * di * N // self.ssm_head_dim * self.ssm_head_dim  # B/C heads
        else:
            ssm = 0
        if self.family == "hybrid":
            # per-layer mamba2 + ONE shared attn+mlp block
            per_layer = ssm
            n += attn + 3 * d * self.d_ff
            n += per_layer * L + 2 * d * L  # norms
            return n
        per_layer = attn + mlp + ssm + 2 * d
        return n + per_layer * L

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        expert = 3 * d * self.d_ff
        mlp = (self.moe_top_k + self.num_shared_experts) * expert + d * self.num_experts
        return n + (attn + mlp + 2 * d) * L
