"""Foundational layers shared by all architecture families.

Conventions:
  * activations [batch, seq, ...]; params are plain dicts of jnp arrays.
  * every init_* returns (params, specs) where specs mirrors params with
    tuples of LOGICAL axis names (mapped to mesh axes in launch/sharding.py).
  * attention is chunked online-softmax (flash-style, lax.scan over q and kv
    chunks) so 32k+ contexts lower with O(S) memory.
  * the vocab-sharded cross-entropy never materializes full logits.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# param declaration helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32))


def dense_init(key, d_in, d_out, spec, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return _normal(key, (d_in, d_out), scale), spec


def shard_tokens(x, enabled: bool):
    """Constrain a [B, S, ...] activation to batch-on-data, rest replicated.

    Pins the token layout at layer boundaries so the SPMD partitioner does
    not alternate between token-sharded and head-sharded layouts across
    blocks (which costs an all-to-all pair per layer — §Perf cell B). Only
    AUTO mesh axes are used, so this is safe inside pod-manual shard_map.
    """
    if not enabled:
        return x
    try:
        m = jax.sharding.get_abstract_mesh()
        names = getattr(m, "axis_names", None)
        if not names or "data" not in names:
            return x
        types = getattr(m, "axis_types", (None,) * len(names))
        auto = {n for n, t in zip(names, types) if "Auto" in str(t)}
        baxes = tuple(a for a in ("pod", "data") if a in auto)
        if not baxes:
            return x
        from jax.sharding import PartitionSpec
        spec = PartitionSpec(baxes if len(baxes) > 1 else baxes[0],
                             *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def shard_heads(x, enabled: bool):
    """Constrain [B, S, H, hd] q/k/v to (batch, None, model, None) when the
    head count divides the model axis — keeps attention head-sharded instead
    of letting the partitioner pick seq sharding (whose chunked-scan
    dynamic-slices lower to per-iteration all-to-alls; §Perf cell B)."""
    if not enabled:
        return x
    try:
        m = jax.sharding.get_abstract_mesh()
        names = getattr(m, "axis_names", None)
        if not names or "model" not in names or x.ndim != 4:
            return x
        types = getattr(m, "axis_types", (None,) * len(names))
        auto = {n for n, t in zip(names, types) if "Auto" in str(t)}
        if "model" not in auto or x.shape[2] % m.shape["model"] != 0:
            return x
        baxes = tuple(a for a in ("pod", "data") if a in auto)
        lead = (baxes if len(baxes) > 1 else baxes[0]) if baxes else None
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(
            x, PartitionSpec(lead, None, "model", None))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": (None,)}
    return ({"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)},
            {"scale": (None,), "bias": (None,)})


def apply_norm(params, x, kind: str, eps: float):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] (int). Pairs (even, odd) rotated."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

_NEG = jnp.float32(-1e30)


def _attn_inner(q, k, v, qpos, kpos, causal):
    """One (q-chunk x kv-chunk) online-softmax pass, scanned over kv chunks.

    q: [B, Cq, K, G, hd]; k/v: [B, nk, Ck, K, hd]; qpos: [Cq]; kpos: [nk, Ck].
    Positions carry NO batch dim so the causal masks stay [Cq, Ck] (tiny,
    loop-hoistable). Each kv-chunk step is rematerialized in backward
    (jax.checkpoint) so the S^2 score/prob tensors are never stored —
    flash-attention memory behaviour from composition.
    """
    B, Cq, K, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs  # [B,Ck,K,hd], [B,Ck,K,hd], [Ck]
        s = jnp.einsum("bqkgh,bckh->bqkgc", q, kc,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = (qpos[:, None] >= kp[None, :]) & (kp >= 0)[None, :]
        else:
            mask = jnp.broadcast_to((kp >= 0)[None, :], (Cq, kp.shape[0]))
        s = jnp.where(mask[None, :, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -0.5 * 1e30)
        p = jnp.exp(s - m_safe[..., None])                   # [B,Cq,K,G,Ck]
        corr = jnp.exp(jnp.maximum(m, -0.5 * 1e30) - m_safe)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, Cq, K, G), _NEG),
            jnp.zeros((B, Cq, K, G), jnp.float32),
            jnp.zeros((B, Cq, K, G, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out


def _flash_fwd_scan(qr, kr, vr, qpos, kpos, causal):
    """Forward over q chunks; returns (out [B,nq,Cq,K,G,hd], lse)."""
    def q_step(_, xs):
        qc, qp = xs
        m, l, acc = _attn_inner_state(qc, kr, vr, qp, kpos, causal)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.maximum(m, -0.5 * 1e30) + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None,
                                   (jnp.moveaxis(qr, 1, 0), qpos))
    return jnp.moveaxis(outs, 0, 1), jnp.moveaxis(lses, 0, 1)


def _attn_inner_state(q, k, v, qpos, kpos, causal):
    """Online-softmax state (m, l, acc) for one q chunk vs all kv chunks."""
    B, Cq, K, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs
        s = jnp.einsum("bqkgh,bckh->bqkgc", q, kc,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = (qpos[:, None] >= kp[None, :]) & (kp >= 0)[None, :]
        else:
            mask = jnp.broadcast_to((kp >= 0)[None, :], (Cq, kp.shape[0]))
        s = jnp.where(mask[None, :, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -0.5 * 1e30)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.maximum(m, -0.5 * 1e30) - m_safe)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, Cq, K, G), _NEG),
            jnp.zeros((B, Cq, K, G), jnp.float32),
            jnp.zeros((B, Cq, K, G, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), kpos))
    return m, l, acc


import functools


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, Cq: int, Ck: int, q_offset: int,
                kv_valid_len):
    """Flash attention with a custom VJP: forward saves only (out, lse);
    backward RECOMPUTES score tiles chunk-by-chunk (never stores S^2).
    lru_cache keeps function identity stable so jit caching works."""

    def reference(q, k, v):
        B, Sq, H, hd = q.shape
        Sk, K = k.shape[1], k.shape[2]
        G = H // K
        nq, nk = Sq // Cq, Sk // Ck
        qr = q.reshape(B, nq, Cq, K, G, hd)
        kr = k.reshape(B, nk, Ck, K, hd)
        vr = v.reshape(B, nk, Ck, K, hd)
        qpos, kpos = _positions(Sq, Sk, nq, nk)
        out, _ = _flash_fwd_scan(qr, kr, vr, qpos, kpos, causal)
        return out.reshape(B, Sq, H, hd).astype(q.dtype)

    def _positions(Sq, Sk, nq, nk):
        kpos = jnp.arange(Sk).reshape(nk, Ck)
        if kv_valid_len is not None:
            kpos = jnp.where(kpos < kv_valid_len, kpos, -1)
        qpos = (q_offset + jnp.arange(Sq)).reshape(nq, Cq)
        return qpos, kpos

    @jax.custom_vjp
    def attn(q, k, v):
        return reference(q, k, v)

    def fwd(q, k, v):
        B, Sq, H, hd = q.shape
        Sk, K = k.shape[1], k.shape[2]
        G = H // K
        nq, nk = Sq // Cq, Sk // Ck
        qr = q.reshape(B, nq, Cq, K, G, hd)
        kr = k.reshape(B, nk, Ck, K, hd)
        vr = v.reshape(B, nk, Ck, K, hd)
        qpos, kpos = _positions(Sq, Sk, nq, nk)
        out, lse = _flash_fwd_scan(qr, kr, vr, qpos, kpos, causal)
        o = out.reshape(B, Sq, H, hd).astype(q.dtype)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        B, Sq, H, hd = q.shape
        Sk, K = k.shape[1], k.shape[2]
        G = H // K
        nq, nk = Sq // Cq, Sk // Ck
        scale = 1.0 / math.sqrt(hd)
        qr = q.reshape(B, nq, Cq, K, G, hd)
        dor = do.reshape(B, nq, Cq, K, G, hd).astype(jnp.float32)
        orr = o.reshape(B, nq, Cq, K, G, hd).astype(jnp.float32)
        delta = jnp.sum(dor * orr, axis=-1)               # [B,nq,Cq,K,G]
        kr = k.reshape(B, nk, Ck, K, hd)
        vr = v.reshape(B, nk, Ck, K, hd)
        qpos, kpos = _positions(Sq, Sk, nq, nk)

        def kv_step(dq_acc, xs):
            kc, vc, kp = xs                               # [B,Ck,K,hd], [Ck]

            def q_step(carry, xs2):
                dk_j, dv_j = carry
                qc, doc, oc_delta, lse_c, qp = xs2
                s = jnp.einsum("bqkgh,bckh->bqkgc", qc, kc,
                               preferred_element_type=jnp.float32) * scale
                if causal:
                    mask = (qp[:, None] >= kp[None, :]) & (kp >= 0)[None, :]
                else:
                    mask = jnp.broadcast_to((kp >= 0)[None, :],
                                            (Cq, kp.shape[0]))
                s = jnp.where(mask[None, :, None, None, :], s, _NEG)
                p = jnp.exp(s - lse_c[..., None])         # [B,Cq,K,G,Ck]
                dv_j = dv_j + jnp.einsum("bqkgc,bqkgh->bckh", p, doc)
                dp = jnp.einsum("bqkgh,bckh->bqkgc", doc,
                                vc.astype(jnp.float32))
                ds = p * (dp - oc_delta[..., None]) * scale
                dk_j = dk_j + jnp.einsum("bqkgc,bqkgh->bckh", ds,
                                         qc.astype(jnp.float32))
                dq_c = jnp.einsum("bqkgc,bckh->bqkgh", ds,
                                  kc.astype(jnp.float32))
                return (dk_j, dv_j), dq_c

            zeros_kv = jnp.zeros((B, Ck, K, hd), jnp.float32)
            (dk_j, dv_j), dq_cs = jax.lax.scan(
                q_step, (zeros_kv, zeros_kv),
                (jnp.moveaxis(qr, 1, 0), jnp.moveaxis(dor, 1, 0),
                 jnp.moveaxis(delta, 1, 0), jnp.moveaxis(lse, 1, 0), qpos))
            dq_acc = dq_acc + jnp.moveaxis(dq_cs, 0, 1)
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((B, nq, Cq, K, G, hd), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(
            kv_step, dq0,
            (jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0), kpos))
        dq = dq.reshape(B, Sq, H, hd).astype(q.dtype)
        dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, K, hd).astype(k.dtype)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, K, hd).astype(v.dtype)
        return dq, dk, dv

    attn.defvjp(fwd, bwd)
    return attn


def chunked_attention(q, k, v, *, causal: bool, chunk: int, q_offset=0,
                      kv_valid_len=None):
    """q: [B,Sq,H,hd], k/v: [B,Sk,K,hd] (GQA: H = K*G). Returns [B,Sq,H,hd].

    Flash-style: forward is an online-softmax double scan; backward is a
    custom VJP that recomputes score tiles (O(S) memory both ways).
    q_offset / kv_valid_len must be static ints here (training/prefill use
    0/None; decode uses ``decode_attention`` instead).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    Cq = min(chunk, Sq)
    Ck = min(chunk, Sk)
    assert (Sq // Cq) * Cq == Sq and (Sk // Ck) * Ck == Sk, \
        "seq must divide by chunk"
    fn = _make_flash(bool(causal), Cq, Ck, int(q_offset),
                     int(kv_valid_len) if kv_valid_len is not None else None)
    return fn(q, k, v)


def decode_attention(q, k, v, cur_index):
    """Single-token attention, un-chunked: q [B,1,H,hd] vs cache [B,S,K,hd].

    Scores memory is O(B*H*S) — small for one query token — and the direct
    einsum lets SPMD derive sequence-parallel decode when the cache's seq dim
    is sharded on "model" (softmax max/sum + p@v contraction become small
    all-reduces instead of a cache all-gather).
    """
    B, _, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qn = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qn.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    valid = (jnp.arange(S) <= cur_index)[None, None, None, :]
    s = jnp.where(valid, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (params + apply), GQA + optional bias + RoPE
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], d, qd, ("embed", "q_heads"))
    p["wk"], s["wk"] = dense_init(ks[1], d, kvd, ("embed", "kv_heads"))
    p["wv"], s["wv"] = dense_init(ks[2], d, kvd, ("embed", "kv_heads"))
    p["wo"], s["wo"] = dense_init(ks[3], qd, d, ("q_heads", "embed"))
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), jnp.float32); s["bq"] = ("q_heads",)
        p["bk"] = jnp.zeros((kvd,), jnp.float32); s["bk"] = ("kv_heads",)
        p["bv"] = jnp.zeros((kvd,), jnp.float32); s["bv"] = ("kv_heads",)
    return p, s


def apply_attention(p, x, cfg, positions, cache=None, cache_index=None):
    """Full-sequence (cache=None) or single-step decode (cache given).

    cache: dict(k=[B,Smax,K,hd], v=[B,Smax,K,hd]); cache_index: current length.
    Returns (out [B,S,D], new_cache).
    """
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, K, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, K, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt).reshape(1, 1, H, hd)
        k = k + p["bk"].astype(dt).reshape(1, 1, K, hd)
        v = v + p["bv"].astype(dt).reshape(1, 1, K, hd)
    q = shard_heads(rope(q, positions, cfg.rope_theta), cfg.constrain_acts)
    k = shard_heads(rope(k, positions, cfg.rope_theta), cfg.constrain_acts)
    v = shard_heads(v, cfg.constrain_acts)

    if cache is None:
        out = chunked_attention(q, k, v, causal=cfg.causal, chunk=cfg.attn_chunk)
        new_cache = None
    else:
        # decode: append this step's k/v, attend over valid prefix
        idx = cache_index
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        out = decode_attention(q, ck, cv, idx)
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(B, S, H * hd) @ p["wo"].astype(dt)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP (swiglu / geglu / gelu)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["wi"], s["wi"] = dense_init(ks[0], d, f, ("embed", "mlp"))
        p["wg"], s["wg"] = dense_init(ks[1], d, f, ("embed", "mlp"))
    else:
        p["wi"], s["wi"] = dense_init(ks[0], d, f, ("embed", "mlp"))
    p["wo"], s["wo"] = dense_init(ks[2], f, d, ("mlp", "embed"))
    return p, s


def apply_mlp(p, x, cfg):
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * h
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(dt), approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings + vocab-sharded chunked cross-entropy
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    """Embedding rows padded to cfg.vocab_padded so the vocab dim shards
    evenly; padded logits are masked to -inf in the loss/decode heads."""
    V = cfg.vocab_padded
    p = {"tok": _normal(key, (V, cfg.d_model), 1.0)}
    s = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["out"] = _normal(k2, (V, cfg.d_model), 1.0 / math.sqrt(cfg.d_model))
        s["out"] = ("vocab", "embed")
    return p, s


def embed_tokens(p, tokens, dtype):
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def unembed_matrix(p):
    return p["out"] if "out" in p else p["tok"]


def chunked_ce_loss(emb_params, hidden, labels, mask, chunk: int,
                    vocab_size: int | None = None):
    """Mean next-token CE without materializing [B,S,V] logits.

    hidden: [B,S,D]; labels/mask: [B,S]. Scans seq chunks; each chunk is
    rematerialized in backward (jax.checkpoint). Padded vocab rows (>=
    vocab_size) are masked out of the partition function.
    """
    W = unembed_matrix(emb_params)  # [Vp, D]
    B, S, D = hidden.shape
    C = min(chunk, S)
    n = S // C
    assert n * C == S
    Vp = W.shape[0]
    vmask = (jnp.arange(Vp) < (vocab_size or Vp)).astype(jnp.float32)
    vneg = (1.0 - vmask) * -1e30

    @jax.checkpoint
    def chunk_loss(hc, lc, mc):
        logits = jnp.einsum("bcd,vd->bcv", hc.astype(jnp.float32),
                            W.astype(jnp.float32)) + vneg
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    def step(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        l, c = chunk_loss(hc, lc, mc)
        return (tot + l, cnt + c), None

    hs = jnp.moveaxis(hidden.reshape(B, n, C, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, C), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, C).astype(jnp.float32), 1, 0)
    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def logits_last(emb_params, hidden_last, vocab_size: int | None = None):
    """Decode-step logits for the final position. hidden_last: [B, D].
    Padded vocab rows masked to -inf (shape stays padded => even shards)."""
    W = unembed_matrix(emb_params)
    logits = jnp.einsum("bd,vd->bv", hidden_last.astype(jnp.float32),
                        W.astype(jnp.float32))
    if vocab_size is not None and vocab_size < W.shape[0]:
        logits = logits + (jnp.arange(W.shape[0]) >= vocab_size) * -1e30
    return logits
