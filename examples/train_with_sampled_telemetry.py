"""End-to-end driver: train a (reduced) LM for a few hundred steps with the
paper's technique active in three places — importance-sampled data, sampled
telemetry, and (on a multi-pod mesh) sampled gradient exchange.

    PYTHONPATH=src python examples/train_with_sampled_telemetry.py \
        [--arch granite-moe-1b-a400m] [--steps 300]
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # repro.launch.train owns the CLI below

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=300)
    args, _ = ap.parse_known_args()
    train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--importance-sampling",
        "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
        "--ckpt-every", "100",
        "--log-every", "20",
    ])
