"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_batched.py [--arch zamba2-2.7b]

Uses the same serve_step the decode_32k / long_500k dry-run cells lower —
including the SSM/hybrid recurrent-state path.
"""
import argparse
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    args, _ = ap.parse_known_args()
    sys.argv = [sys.argv[0]]
    serve_main(["--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"])
