"""Sample-based center optimization over a metric point set (paper §7).

    PYTHONPATH=src python examples/cluster_centers.py

Builds a ClusterEngine over a synthetic Gaussian mixture — a device-
resident sampled point slab whose probabilities universally upper-bound
every center-set objective — then optimizes centers ENTIRELY from the
sample: every local-search round scores all candidate swaps in ONE fused
service-cost launch (kernels.servicecost), and the result is cross-checked
against ground-truth costs over the full point set.
"""
import numpy as np

from repro.core.costs import cost_query, exact_service_costs
from repro.launch.cluster import ClusterEngine, exact_scorer, kcenter, \
    local_search


def main():
    rng = np.random.default_rng(0)
    true_centers = np.array([[0., 0.], [9., 1.], [4., 8.], [-6., 6.]],
                            np.float32)
    X = (true_centers[rng.integers(0, 4, 4000)]
         + rng.normal(0, 0.8, (4000, 2))).astype(np.float32)

    # stream the points in chunks — the engine's resident slab absorbs each
    # with the donated device fold and stays a few hundred slots total
    eng = ClusterEngine(dim=2, k=96, mu=2.0, seed=0)
    for chunk in np.array_split(X, 8):
        eng.absorb(chunk)
    print(f"absorbed n={len(X)} in 8 chunks; "
          f"slab members={int(np.asarray(eng.sample()[2]).sum())}, "
          f"HT count estimate={eng.total_count():.0f}")

    for mu, name in ((2.0, "k-means"), (1.0, "k-median")):
        res = local_search(eng, k=4, mu=mu, rounds=16, n_cand=32)
        exact = float(exact_service_costs(X, cost_query(res.centers, mu))[0])
        ref = local_search(eng, k=4, mu=mu, rounds=16, n_cand=32,
                           scorer=exact_scorer(X))
        ref_cost = float(exact_service_costs(
            X, cost_query(ref.centers, mu))[0])
        print(f"[{name}] centers:\n{np.round(res.centers, 2)}")
        print(f"[{name}] est cost {res.est_cost:.1f} | exact cost of result "
              f"{exact:.1f} | exact-scored search {ref_cost:.1f} "
              f"(ratio {exact / ref_cost:.3f}) | rounds {res.rounds}")

    kc = kcenter(eng, 4)
    print(f"[k-center] radius {kc.radius:.2f}; estimated coverage "
          f"{kc.coverage_est:.0f} of {kc.total_est:.0f}")


if __name__ == "__main__":
    main()
