"""Sampled gradient exchange demo (paper technique -> collective term).

    PYTHONPATH=src python examples/gradient_compression_demo.py

Runs the same training twice on a simulated 2x2x2 (pod,data,model) mesh:
once with dense cross-pod all-reduce, once with the multi-objective sampled
exchange (distopt.compression), and reports loss curves + wire bytes.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import get_smoke_config  # noqa: E402
from repro.launch import steps as St  # noqa: E402
from repro.launch.mesh import mesh_context  # noqa: E402
from repro.models import model as Mod  # noqa: E402
from repro.optim import adamw  # noqa: E402


def run(compress):
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("qwen2-1.5b")
    key = jax.random.PRNGKey(0)
    opt = adamw.OptConfig(total_steps=60, warmup_steps=2, peak_lr=5e-3)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        step, sh = St.make_train_step(
            cfg, opt, mesh, donate=False,
            compress=dict(k=256, min_size=1024) if compress else None)
        state = jax.device_put(
            {"params": params, "opt": adamw.init_opt_state(params)}, sh)
        batch = {"tokens": jax.random.randint(key, (8, 64), 0,
                                              cfg.vocab_size)}
        losses = []
        for i in range(12):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses


if __name__ == "__main__":
    dense = run(False)
    sampled = run(True)
    n_params = sum(x.size for x in jax.tree.leaves(
        Mod.init_model(jax.random.PRNGKey(0),
                       get_smoke_config("qwen2-1.5b"))[0]))
    print("step | dense loss | sampled-exchange loss")
    for i, (d, s) in enumerate(zip(dense, sampled)):
        print(f"{i:4d} | {d:10.4f} | {s:10.4f}")
    wire = 3 * 256 * 12  # 3k slots x (idx,val,prob) per big leaf
    print(f"\ncross-pod bytes per big leaf: dense = leaf_size*4, "
          f"sampled = {wire} (fixed) — see EXPERIMENTS.md §Perf for the "
          f"production-mesh collective-term numbers")
