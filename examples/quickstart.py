"""Quickstart: multi-objective weighted sampling on a keyed data set.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core workflow end to end: build one universal
monotone sample of a 100k-key data set and answer MANY different segment
f-statistics from it — count, sum, thresholds, caps, moments — each with
gold-standard accuracy (CV <= 1/sqrt(q(k-1)), paper Thm 5.1/§5.1).
"""
import numpy as np
import repro.core as C

rng = np.random.default_rng(0)
n, k = 100_000, 64

# a keyed data set: e.g. per-user activity with heavy-tailed weights
keys = np.arange(n, dtype=np.int32)
weights = rng.lognormal(0.0, 2.0, n).astype(np.float32)
active = np.ones(n, bool)
domain = rng.integers(0, 8, n)  # segment attribute

# ---- ONE sample serves all monotone statistics --------------------------
sample = C.universal_monotone_sample(keys, weights, active, k, seed=42)
print(f"sample size: {int(sample.member.sum())} of {n} keys "
      f"(bound k ln n = {C.expected_size_bound(n, k):.0f})")

segment = domain == 3
for f in [C.COUNT, C.SUM, C.thresh(5.0), C.cap(2.0), C.moment(1.5)]:
    est = float(C.estimate(f, weights, sample.prob, sample.member, segment))
    exact = float(C.exact(f, weights, active, segment))
    q = exact / float(C.exact(f, weights, active))
    print(f"  Q({f.name:10s}, domain=3): est {est:12.1f}   "
          f"exact {exact:12.1f}   err {abs(est/exact-1)*100:5.1f}%   "
          f"CV bound {C.cv_bound(q, k)*100:.1f}%")

# ---- mergeability: shard the data, sketch each shard, merge -------------
cap_sz = C.sketch_capacity(n, k)
parts = np.array_split(np.arange(n), 16)
sketches = [C.build_sketch(keys[p], weights[p], active[p], k, cap_sz, seed=42)
            for p in parts]
merged = sketches[0]
for s in sketches[1:]:
    merged = C.merge_sketches(merged, s)
print(f"merged-sketch sum estimate: {float(C.sketch_estimate(merged, C.SUM)):.1f}"
      f"  (exact {weights.sum():.1f}) — distributed == centralized")
