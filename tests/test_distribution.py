"""Distribution-layer tests needing >1 device: run via subprocess with
forced host device count (kept OUT of conftest so other tests see 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.registry import get_smoke_config
    from repro.launch import steps as St
    from repro.launch import sharding as Sh
    from repro.optim import adamw
    from repro.models import model as Mod
    from repro.launch.mesh import mesh_context

    out = {}
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("qwen2-1.5b")
    key = jax.random.PRNGKey(0)
    opt = adamw.OptConfig(total_steps=50, warmup_steps=2, peak_lr=5e-3)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        # dense
        step, sh = St.make_train_step(cfg, opt, mesh, donate=False)
        st = jax.device_put({"params": params,
                             "opt": adamw.init_opt_state(params)}, sh)
        losses = []
        for i in range(6):
            st, m = step(st, batch)
            losses.append(float(m["loss"]))
        out["dense"] = losses
        # compressed (sampled cross-pod exchange)
        stepc, _ = St.make_train_step(cfg, opt, mesh, donate=False,
                                      compress=dict(k=512, min_size=1024))
        stc = jax.device_put({"params": params,
                              "opt": adamw.init_opt_state(params)}, sh)
        closses = []
        for i in range(6):
            stc, m = stepc(stc, batch)
            closses.append(float(m["loss"]))
        out["compressed"] = closses
        # microbatch+multipod
        stepm, _ = St.make_train_step(cfg, opt, mesh, donate=False,
                                      microbatch=2)
        stm = jax.device_put({"params": params,
                              "opt": adamw.init_opt_state(params)}, sh)
        stm, m = stepm(stm, batch)
        out["microbatch_loss"] = float(m["loss"])
        out["dense_first"] = losses[0]
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def multi_device_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_multipod_dense_training_converges(multi_device_result):
    l = multi_device_result["dense"]
    assert l[-1] < l[0] * 0.6


def test_sampled_gradient_exchange_converges(multi_device_result):
    l = multi_device_result["compressed"]
    assert l[-1] < l[0] * 0.8  # unbiased but noisier than dense


def test_microbatch_matches_dense_loss(multi_device_result):
    assert abs(multi_device_result["microbatch_loss"]
               - multi_device_result["dense_first"]) < 5e-2


def test_partition_rules_divisibility():
    """Non-divisible dims must be replicated, divisible sharded."""
    import jax
    from repro.launch.sharding import logical_to_pspec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"model": 16, "data": 16}
        axis_names = ("data", "model")
    p = logical_to_pspec(("embed", "q_heads"), (1536, 1536), FakeMesh())
    assert p == jax.sharding.PartitionSpec(None, "model")
    p = logical_to_pspec(("vocab", "embed"), (49155, 1024), FakeMesh())
    assert p == jax.sharding.PartitionSpec()  # 49155 % 16 != 0 -> replicate
    p = logical_to_pspec(("expert", "embed", "mlp"), (32, 1024, 512),
                         FakeMesh())
    assert p == jax.sharding.PartitionSpec("model")  # first eligible only
