"""Metric-space clustering subsystem: fused service-cost kernel vs the XLA
oracle across mu x Q x schemes, single-launch flatness in Q and |C|,
ball-density edge cases, the coords-aligned streaming ClusterEngine, and
the sample-based optimizer vs its exact-cost twin on small instances."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.core.costs import (ball_query, cost_query, cost_table,
                              encode_cost_queries, pad_cost_table)
from repro.kernels import ref as R
from repro.kernels.servicecost import service_cost_slab
from repro.launch.cluster import (ClusterEngine, exact_scorer, kcenter,
                                  local_search)
from tests.test_batched_multiobj import _count_pallas_calls


def _points(n=400, dim=3, seed=0, spread=6.0):
    rng = np.random.default_rng(seed)
    ctrs = rng.normal(0, spread, (4, dim))
    return (ctrs[rng.integers(0, 4, n)]
            + rng.normal(0, 0.7, (n, dim))).astype(np.float32)


_ENGINES = {}


def _engine(scheme):
    if scheme not in _ENGINES:
        _ENGINES[scheme] = ClusterEngine.fit(_points(), k=48, mu=2.0,
                                             scheme=scheme, seed=3)
    return _ENGINES[scheme]


def _queries(q, mu, dim=3, seed=1):
    """q queries cycling through ragged cost sets and ball rows."""
    rng = np.random.default_rng(seed)
    X = _points(seed=0)
    out = []
    for i in range(q):
        m = int(rng.integers(1, 6))
        ctr = X[rng.integers(0, X.shape[0], m)] + rng.normal(0, 0.1, (m, dim))
        if i % 4 == 3:
            out.append(ball_query(ctr, radius=float(rng.random() * 5)))
        else:
            out.append(cost_query(ctr, mu=mu))
    return encode_cost_queries(out)


# ------------------------------------------------ kernel vs oracles
@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("q", [1, 16, 128])
@pytest.mark.parametrize("mu", [1.0, 2.0])
def test_service_cost_kernel_vs_oracle(scheme, q, mu):
    eng = _engine(scheme)
    pts, probs, member = eng.sample()
    table = _queries(q, mu)
    got = np.asarray(service_cost_slab(pts, probs, member, table))
    xla = np.asarray(C.estimate_service_costs(pts, probs, member, table,
                                              use_kernels=False))
    ref = np.asarray(R.service_cost_ref(pts, probs, member, table))
    assert got.shape == (q,)
    np.testing.assert_allclose(got, xla, rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=1e-3)
    assert np.all(np.isfinite(got)) and np.all(got >= 0)


def test_service_cost_weighted_points():
    eng = _engine("ppswor")
    pts, probs, member = eng.sample()
    pw = np.random.default_rng(5).random(pts.shape[0]).astype(np.float32)
    table = _queries(8, 2.0)
    got = np.asarray(service_cost_slab(pts, probs, member, table,
                                       point_weights=pw))
    xla = np.asarray(C.estimate_service_costs(pts, probs, member, table,
                                              point_weights=pw,
                                              use_kernels=False))
    np.testing.assert_allclose(got, xla, rtol=2e-4, atol=1e-3)


def test_pad_rows_estimate_exactly_zero():
    eng = _engine("ppswor")
    pts, probs, member = eng.sample()
    table = pad_cost_table(_queries(5, 1.0), 16)
    for uk in (True, False):
        got = np.asarray(C.estimate_service_costs(pts, probs, member, table,
                                                  use_kernels=uk))
        assert got.shape == (16,)
        np.testing.assert_array_equal(got[5:], np.zeros(11, np.float32))


def test_encode_cost_queries_validation():
    with pytest.raises(ValueError):
        encode_cost_queries([])
    with pytest.raises(ValueError):
        encode_cost_queries([cost_query(np.zeros((2, 3))),
                             cost_query(np.zeros((2, 4)))])
    with pytest.raises(ValueError):
        encode_cost_queries([cost_query(np.zeros((4, 3)))], cmax=2)
    t = encode_cost_queries([cost_query(np.zeros((1, 3))),
                             cost_query(np.zeros((4, 3)))])
    assert t.centers.shape == (2, 4, 3)
    assert t.cvalid.sum() == 5


# ------------------------------------------------ single-launch flatness
@pytest.mark.parametrize("q,cm", [(1, 2), (16, 8), (128, 8), (16, 64)])
def test_service_cost_launch_count_flat_in_Q_and_C(q, cm):
    """ONE pallas launch per batch, for every (Q, |C|) combination."""
    rng = np.random.default_rng(0)
    pts = rng.normal(0, 1, (300, 3)).astype(np.float32)
    probs = np.clip(rng.random(300), 0.1, 1).astype(np.float32)
    member = rng.random(300) > 0.5
    table = cost_table(rng.normal(0, 1, (q, cm, 3)).astype(np.float32), 2.0)
    jx = jax.make_jaxpr(
        lambda p, pr, m, t: service_cost_slab(p, pr, m, t))(
            jnp.asarray(pts), jnp.asarray(probs), jnp.asarray(member),
            C.CostTable(*(jnp.asarray(x) for x in table)))
    assert _count_pallas_calls(jx.jaxpr) == 1


# ------------------------------------------------ ball-density edges
def test_ball_density_edge_cases():
    X = _points(seed=2)
    eng = ClusterEngine.fit(X, k=48, mu=1.0, seed=7)
    pts, probs, member = eng.sample()
    diam = float(np.max(np.linalg.norm(X[None] - X[:, None], axis=-1)))

    # r >= diameter: every point covered -> the estimate IS the HT count
    cover = eng.ball_density(X[0], diam * 1.01)
    assert cover == pytest.approx(eng.total_count(), rel=1e-5)
    assert cover == pytest.approx(len(X), rel=0.35)  # CV sanity

    # r = 0: no blow-up, kernel == oracle exactly, bounded by the count
    t0 = encode_cost_queries([ball_query(X[0], 0.0),
                              ball_query(X[0] + 100.0, 0.0)])
    k0 = np.asarray(service_cost_slab(pts, probs, member, t0))
    x0 = np.asarray(C.estimate_service_costs(pts, probs, member, t0,
                                             use_kernels=False))
    np.testing.assert_allclose(k0, x0, rtol=1e-5)
    assert np.all(np.isfinite(k0)) and np.all(k0 >= 0)
    assert k0[1] == 0.0                       # far empty ball: exactly 0
    assert k0[0] <= eng.total_count() + 1e-3

    # empty center set: 0 in both modes
    te = C.CostTable(centers=np.zeros((1, 2, X.shape[1]), np.float32),
                     cvalid=np.zeros((1, 2), bool),
                     mu=np.ones(1, np.float32), param=np.ones(1, np.float32),
                     mode=np.array([C.MODE_BALL], np.int32))
    assert float(service_cost_slab(pts, probs, member, te)[0]) == 0.0


def test_ball_density_monotone_in_radius():
    eng = _engine("ppswor")
    q = _points(seed=0)[0]
    ests = [eng.ball_density(q, r) for r in (0.5, 1.5, 4.0, 50.0)]
    assert all(a <= b + 1e-4 for a, b in zip(ests, ests[1:]))


# ------------------------------------------------ engine: streaming state
def test_cluster_engine_streaming_coords_aligned():
    X = _points(n=500, seed=4)
    eng = ClusterEngine(dim=3, k=48, mu=2.0, seed=1)
    for i in range(3):
        eng.absorb(X[i::3])
    assert eng.epoch == 3
    sk = eng._sketch
    keys = np.asarray(sk.keys)
    coords = np.asarray(eng._coords)
    # recover each absorbed chunk's global keys -> original rows
    order = np.concatenate([np.arange(500)[i::3] for i in range(3)])
    for s in np.nonzero(np.asarray(sk.valid))[0]:
        np.testing.assert_array_equal(coords[s], X[order[keys[s]]])
    # estimates reflect the union: cost of the true centers within HT error
    est = eng.clustering_cost(X[:4])
    exact = float(C.exact_service_costs(X, cost_query(X[:4], 2.0))[0])
    assert est == pytest.approx(exact, rel=0.5)


def test_cluster_engine_sample_survives_absorb():
    """A handed-out sample() must stay readable after the next (donated)
    absorb — same guard as the query engine's merged-slab hand-out."""
    rng = np.random.default_rng(9)
    eng = ClusterEngine(dim=2, k=32, seed=0)
    eng.absorb(rng.normal(0, 1, (200, 2)).astype(np.float32))
    coords, probs, member = eng.sample()
    before = float(jnp.sum(jnp.where(member, probs, 0.0)))
    eng.absorb(rng.normal(0, 1, (200, 2)).astype(np.float32))
    assert float(jnp.sum(jnp.where(member, probs, 0.0))) == before
    assert coords.shape == eng.sample()[0].shape


def test_service_costs_q_chunking_matches_one_shot():
    """Q past the per-launch ceiling is split transparently; estimates
    match the unchunked XLA batch."""
    eng = _engine("ppswor")
    eng.q_max = 32
    table = _queries(150, 2.0)
    got = eng.service_costs(table)
    pts, probs, member = eng.sample()
    want = np.asarray(C.estimate_service_costs(pts, probs, member, table,
                                               use_kernels=False))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-3)
    eng.q_max = 128


def test_cluster_engine_explicit_keys_never_collide_with_default():
    """A default-keyed absorb after an explicit-keyed one must mint fresh
    ids — colliding ids would pair one point's prob with another's coords."""
    rng = np.random.default_rng(13)
    eng = ClusterEngine(dim=2, k=32, seed=0)
    X1 = rng.normal(0, 1, (100, 2)).astype(np.float32)
    X2 = rng.normal(5, 1, (100, 2)).astype(np.float32)
    eng.absorb(X1, keys=np.arange(40, 140))
    eng.absorb(X2)                                  # must start at key 140
    sk = eng._sketch
    keys = np.asarray(sk.keys)[np.asarray(sk.valid)]
    coords = np.asarray(eng._coords)[np.asarray(sk.valid)]
    both = np.concatenate([X1, X2])
    lookup = {40 + i: both[i] for i in range(200)}
    for ky, co in zip(keys, coords):
        np.testing.assert_array_equal(co, lookup[int(ky)])


def test_local_search_zero_rounds_returns_scored_init():
    eng = _engine("ppswor")
    res = local_search(eng, k=3, rounds=0, n_cand=8)
    assert res.rounds == 0 and len(res.history) == 1
    assert res.est_cost == pytest.approx(
        float(eng.service_costs(cost_query(res.centers, eng.mu))[0]),
        rel=1e-6)


def test_cluster_engine_absorb_grows_count():
    eng = ClusterEngine(dim=2, k=32, seed=0)
    rng = np.random.default_rng(0)
    eng.absorb(rng.normal(0, 1, (200, 2)).astype(np.float32))
    c1 = eng.total_count()
    eng.absorb(rng.normal(0, 1, (200, 2)).astype(np.float32))
    assert eng.total_count() > c1
    assert eng.epoch == 2


# ------------------------------------------------ optimizer vs exact oracle
@pytest.mark.parametrize("inst,mu", [(0, 2.0), (1, 1.0), (2, 2.0)])
def test_local_search_matches_exact_on_small_instances(inst, mu):
    """Acceptance: the sample-scored search's EXACT cost is within the HT
    estimate's error bound of the exact-scored search's cost, >= 3 small
    synthetic instances."""
    X = _points(n=300, dim=2, seed=10 + inst, spread=7.0)
    eng = ClusterEngine.fit(X, k=64, mu=mu, seed=inst)
    res_s = local_search(eng, k=3, mu=mu, rounds=10, n_cand=16)
    res_e = local_search(eng, k=3, mu=mu, rounds=10, n_cand=16,
                         scorer=exact_scorer(X))
    ex_s = float(C.exact_service_costs(X, cost_query(res_s.centers, mu))[0])
    ex_e = float(C.exact_service_costs(X, cost_query(res_e.centers, mu))[0])
    # HT error bound at the slab's sample size (cv_bound, q=1), 3 sigma
    bound = 3.0 * C.cv_bound(1.0, eng.k)
    assert ex_s <= ex_e * (1.0 + bound) + 1e-6
    # the search's own estimate agrees with ground truth within the bound
    assert res_s.est_cost == pytest.approx(ex_s, rel=bound)
    # history is monotone improving
    assert all(a >= b for a, b in zip(res_s.history, res_s.history[1:]))


def test_kcenter_covers_sample():
    X = _points(n=400, dim=2, seed=20, spread=10.0)
    eng = ClusterEngine.fit(X, k=64, mu=1.0, seed=0)
    kc = kcenter(eng, 4)
    assert kc.centers.shape == (4, 2)
    # at the returned radius every sampled point is served -> the estimated
    # coverage equals the estimated total exactly (same HT sum)
    assert kc.coverage_est == pytest.approx(kc.total_est, rel=1e-5)
    # well-separated clusters: radius far below the cluster spread
    assert kc.radius < 6.0


# ------------------------------------------------ metric-domain refactor
def test_farthest_point_jit_matches_host_loop():
    """The lax.fori_loop traversal must reproduce the seed's sequential
    host loop exactly (same columns, same argmax tie-breaks)."""
    from repro.core.metric_domains import _pairwise_dist, \
        farthest_point_anchors
    X = jnp.asarray(_points(n=200, seed=6))
    anchors = [0]
    d_min = _pairwise_dist(X, X[:1]).reshape(-1)
    for _ in range(7):
        nxt = int(jnp.argmax(d_min))
        anchors.append(nxt)
        d_min = jnp.minimum(d_min,
                            _pairwise_dist(X, X[nxt:nxt + 1]).reshape(-1))
    got, got_dmin = farthest_point_anchors(X, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(anchors))
    np.testing.assert_array_equal(np.asarray(got_dmin), np.asarray(d_min))


def test_multisketch_runtime_seed_matches_static():
    """The runtime-seed build override (one executable for many seeds)
    must reproduce the static-seed build bit for bit."""
    rng = np.random.default_rng(12)
    keys = np.arange(700, dtype=np.int32)
    w = rng.lognormal(0, 1, 700).astype(np.float32)
    objs = ((C.SUM, 12), (C.COUNT, 6))
    for seed in (3, 9):
        a = C.multisketch_build(
            C.MultiSketchSpec(objectives=objs, seed=seed), keys, w)
        b = C.multisketch_build(
            C.MultiSketchSpec(objectives=objs, seed=0), keys, w, seed=seed)
        for name, x, y in zip(a._fields, a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)


def test_metric_sample_is_sketch_backed():
    """universal_metric_sample == the slab scattered back to a dense mask,
    and many seeds share one compiled build (runtime-seed path)."""
    from repro.core.metric_domains import metric_sample_sketch
    X = _points(n=300, seed=8)
    s = C.universal_metric_sample(X, 24, seed=5)
    ms, spec = metric_sample_sketch(X, 24, seed=5)
    assert spec.seed == 5 and spec.scheme == "ppswor"
    sk = ms.sketch
    keys = np.asarray(sk.keys)
    member_slots = np.asarray(sk.member) & np.asarray(sk.valid)
    dense = np.zeros(300, bool)
    dense[keys[member_slots]] = True
    np.testing.assert_array_equal(np.asarray(s.member), dense)
    assert np.all((np.asarray(s.prob) > 0) == dense)
    # slab coords gather the member points
    np.testing.assert_array_equal(
        np.asarray(ms.coords)[member_slots], X[keys[member_slots]])
