"""MultiSketch subsystem tests: streaming-fold / merge / sharded-build
equivalence with the one-shot sample (exactness acceptance criteria), the
Pallas compaction kernel, and collector segment-query accuracy."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.telemetry.stats import StatsCollector, TelemetryConfig


def _objectives(nf):
    pool = [(C.SUM, 16), (C.COUNT, 8), (C.thresh(2.0), 12), (C.cap(1.5), 8),
            (C.moment(1.5), 8), (C.thresh(0.5), 8), (C.cap(4.0), 8),
            (C.moment(0.5), 8)]
    return tuple(pool[:nf])


def _data(n=2500, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.permutation(np.arange(5, 5 + n)).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    return keys, w


def _members(sk):
    m = np.asarray(sk.member)
    return dict(zip(np.asarray(sk.keys)[m].tolist(),
                    np.asarray(sk.probs)[m].tolist()))


def _reference(keys, w, objs, scheme, seed):
    ref = C.multi_bottomk_sample(keys, w, np.ones(len(keys), bool), objs,
                                 scheme=scheme, seed=seed)
    m = np.asarray(ref.member)
    return (dict(zip(keys[m].tolist(), np.asarray(ref.prob)[m].tolist())),
            np.asarray(ref.taus))


def _assert_same_sample(got: dict, want: dict):
    assert set(got) == set(want), sorted(set(got) ^ set(want))[:5]
    for k in want:
        assert abs(got[k] - want[k]) < 1e-5, (k, got[k], want[k])


@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("nf", [1, 3, 8])
def test_streaming_fold_matches_one_shot(scheme, nf):
    """Absorbing any chunking in any order == one-shot sample (member set,
    probs AND taus) — the §3.3 mergeability acceptance criterion."""
    keys, w = _data()
    objs = _objectives(nf)
    spec = C.MultiSketchSpec(objectives=objs, scheme=scheme, seed=11)
    want, want_taus = _reference(keys, w, objs, scheme, 11)

    rng = np.random.default_rng(1)
    for m, order_seed in ((3, 0), (7, 1)):
        perm = np.random.default_rng(order_seed).permutation(len(keys))
        st = C.multisketch_empty(spec)
        for ch in np.array_split(perm, m):
            st = C.multisketch_absorb(st, keys[ch], w[ch], spec=spec)
        _assert_same_sample(_members(st), want)
        np.testing.assert_allclose(np.asarray(st.taus), want_taus, rtol=1e-6)


def test_merge_and_merge_stacked_match_one_shot():
    keys, w = _data(n=3000, seed=3)
    objs = _objectives(3)
    spec = C.MultiSketchSpec(objectives=objs, seed=2)
    want, want_taus = _reference(keys, w, objs, "ppswor", 2)

    halves = np.array_split(np.arange(len(keys)), 2)
    a = C.multisketch_build(spec, keys[halves[0]], w[halves[0]])
    b = C.multisketch_build(spec, keys[halves[1]], w[halves[1]])
    m = C.multisketch_merge(spec, a, b)
    _assert_same_sample(_members(m), want)

    parts = [C.multisketch_build(spec, keys[i::4], w[i::4])
             for i in range(4)]
    stacked = C.MultiSketch(*jax.tree.map(lambda *xs: jnp.stack(xs), *parts))
    ms = C.multisketch_merge_stacked(spec, stacked)
    _assert_same_sample(_members(ms), want)
    np.testing.assert_allclose(np.asarray(ms.taus), want_taus, rtol=1e-6)


def test_merge_dedups_by_max_weight():
    """A key seen by two parts keeps max w (paper's merged-weight rule)."""
    spec = C.MultiSketchSpec(objectives=((C.SUM, 4),), seed=0)
    a = C.multisketch_build(spec, np.arange(6), np.full(6, 2.0, np.float32))
    b = C.multisketch_build(spec, np.arange(6),
                            np.array([9., 1., 1., 1., 1., 1.], np.float32))
    m = C.multisketch_merge(spec, a, b)
    got_w = {int(k): float(v) for k, v, ok in
             zip(np.asarray(m.keys), np.asarray(m.weights),
                 np.asarray(m.valid)) if ok}
    assert got_w[0] == 9.0
    assert all(v == 2.0 for k, v in got_w.items() if k != 0)


def test_inactive_duplicate_does_not_shadow_observation():
    """Regression: an INVALID higher-weight occurrence of a key in the same
    fold must not knock out the valid observation via the dedup mask."""
    spec = C.MultiSketchSpec(objectives=((C.SUM, 4),), seed=0)
    st = C.multisketch_empty(spec)
    st = C.multisketch_absorb(st, np.array([7, 7]),
                              np.array([5.0, 3.0], np.float32),
                              np.array([False, True]), spec=spec)
    m = np.asarray(st.member)
    assert int(m.sum()) == 1
    assert int(np.asarray(st.keys)[m][0]) == 7
    assert float(np.asarray(st.weights)[m][0]) == 3.0


def test_xla_and_kernel_paths_identical():
    keys, w = _data(n=2048, seed=5)
    objs = _objectives(3)
    spec = C.MultiSketchSpec(objectives=objs, seed=7)
    a = C.multisketch_build(spec, keys, w, use_kernels=True)
    b = C.multisketch_build(spec, keys, w, use_kernels=False)
    np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
    np.testing.assert_array_equal(np.asarray(a.probs), np.asarray(b.probs))
    np.testing.assert_array_equal(np.asarray(a.taus), np.asarray(b.taus))


def test_compact_kernel_priority_and_dedup():
    """kernels.compact: members (weight desc) first, aux next, dups/invalid
    dropped — against a plain-numpy oracle."""
    from repro.kernels.compact import compact_take
    keys = jnp.asarray([-1, 2, 2, 3, 5, 5, 7, 9], jnp.int32)  # key-sorted
    w = jnp.asarray([9., 5., 4., 1., 7., 2., 3., 6.], jnp.float32)
    member = jnp.asarray([1, 0, 0, 1, 1, 0, 0, 0], bool)
    keep = jnp.asarray([1, 1, 1, 1, 1, 1, 0, 1], bool)
    take, valid = compact_take(keys, w, member, keep, 6)
    # retained: members {3(w1), 5(w7)} (slot0 invalid key, dup 5 dropped),
    # then aux {2(w5), 9(w6)}; dup-2, non-keep-7 dropped
    assert np.asarray(valid).tolist() == [True] * 4 + [False] * 2
    assert np.asarray(take)[:4].tolist() == [4, 3, 7, 1]


def test_stats_collector_streaming_and_segments():
    """Device-fold collector: chunked absorb accuracy on whole-set and
    segment queries vs exact sums (satellite acceptance)."""
    tel = StatsCollector(TelemetryConfig(k=48, capacity=512, seed=9))
    rng = np.random.default_rng(0)
    all_k, all_w = [], []
    for step in range(12):
        m = int(rng.integers(40, 160))           # ragged chunks
        w = rng.lognormal(0, 1, m).astype(np.float32)
        keys = step * 1000 + np.arange(m)
        tel.absorb(keys, w)
        all_k.append(keys)
        all_w.append(w)
    keys = np.concatenate(all_k)
    w = np.concatenate(all_w)
    slack = 4 / np.sqrt(47)                      # ~4 sigma at k=48
    assert abs(tel.query(C.SUM) / w.sum() - 1) < slack
    assert abs(tel.query(C.COUNT) / len(w) - 1) < slack
    # segment query: keys from steps >= 6, routed via sketch_estimate
    seg = lambda k: k >= 6000
    exact = w[keys >= 6000].sum()
    est = tel.query(C.SUM, segment_fn=seg)
    assert abs(est / exact - 1) < 2 * slack

    # merge_from: two collectors over disjoint streams == their union
    t2 = StatsCollector(TelemetryConfig(k=48, capacity=512, seed=9))
    t2.absorb(np.arange(50) + 500_000, np.ones(50, np.float32))
    tel.merge_from(t2)
    assert abs(tel.query(C.SUM) / (w.sum() + 50) - 1) < slack


def test_stats_collector_warns_once_on_overflow():
    """Satellite: a saturated pool (S ∪ Z possibly truncated) must raise
    a RuntimeWarning at query time — exactly once per collector — and
    expose the flag via ``.overflow``."""
    import warnings
    tel = StatsCollector(TelemetryConfig(k=48, capacity=64, chunk=64))
    # skewed weights: the SUM and COUNT bottom-k samples diverge, so
    # |S ∪ Z| wants ~2k slots and the 64-slot pool saturates
    w = np.random.default_rng(0).lognormal(0, 2, 512).astype(np.float32)
    tel.absorb(np.arange(512), w)
    assert tel.overflow
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        tel.query(C.SUM)
        tel.query(C.COUNT)               # second query: no second warning
    hits = [w for w in rec if "overflowed" in str(w.message)]
    assert len(hits) == 1 and issubclass(hits[0].category, RuntimeWarning)

    ok = StatsCollector(TelemetryConfig(k=8, capacity=512))
    ok.absorb(np.arange(64), np.ones(64, np.float32))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ok.query(C.SUM)
    assert not ok.overflow
    assert not [w for w in rec if "overflowed" in str(w.message)]


def test_absorb_is_jit_cached_and_donated():
    """The fold reuses one compiled executable across same-shape chunks."""
    spec = C.MultiSketchSpec(objectives=((C.SUM, 8), (C.COUNT, 8)), seed=1)
    st = C.multisketch_empty(spec)
    from repro.core.multi_sketch import _absorb_jit
    misses0 = _absorb_jit._cache_size()
    for i in range(4):
        st = C.multisketch_absorb(st, np.arange(i * 64, (i + 1) * 64),
                                  np.ones(64, np.float32), spec=spec)
    assert _absorb_jit._cache_size() == misses0 + 1


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax
    import repro.core as C
    from repro.launch.summary import sharded_multisketch

    rng = np.random.default_rng(4)
    n = 4096
    keys = rng.permutation(np.arange(n)).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    mesh = jax.make_mesh((4,), ("data",))
    out = {}
    for nf, objs in (
            (1, ((C.SUM, 16),)),
            (3, ((C.SUM, 16), (C.COUNT, 8), (C.thresh(2.0), 12))),
            (8, ((C.SUM, 8), (C.COUNT, 8), (C.thresh(2.0), 8),
                 (C.cap(1.5), 8), (C.moment(1.5), 8), (C.thresh(0.5), 8),
                 (C.cap(4.0), 8), (C.moment(0.5), 8)))):
        spec = C.MultiSketchSpec(objectives=objs, seed=13)
        sk = sharded_multisketch(spec, mesh, keys, w)
        ref = C.multi_bottomk_sample(keys, w, np.ones(n, bool), objs,
                                     scheme="ppswor", seed=13)
        m = np.asarray(sk.member)
        got = dict(zip(np.asarray(sk.keys)[m].tolist(),
                       np.asarray(sk.probs)[m].tolist()))
        rm = np.asarray(ref.member)
        want = dict(zip(keys[rm].tolist(),
                        np.asarray(ref.prob)[rm].tolist()))
        ok = (set(got) == set(want)
              and all(abs(got[k] - want[k]) < 1e-5 for k in want)
              and np.allclose(np.asarray(sk.taus), np.asarray(ref.taus),
                              rtol=1e-6))
        out[str(nf)] = bool(ok)
    print("RESULT " + json.dumps(out))
""")


def test_sharded_build_matches_one_shot_multidevice():
    """shard_map local-build -> all_gather -> one re-selection equals the
    one-shot sample on a real 4-device (host) mesh, |F| in {1, 3, 8}."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out == {"1": True, "3": True, "8": True}
