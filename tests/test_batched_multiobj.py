"""Single-launch batched multi-objective bottom-k pipeline.

Agreement of the fused kernel chain (seeds -> batched block-select ->
batched merge -> vectorized estimate) with the core reference path on
shared u_x, across schemes, ragged n, and |F|; plus a launch-count
regression: the number of pallas_call launches must NOT grow with |F|.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
import repro.kernels as K
from repro.kernels import ref as R
from repro.kernels.ops import multi_objective_bottomk_kernel, statfn_of

# (kind, param) pools — every family, several params
_OBJ_POOL = ((0, 0.0), (1, 0.0), (2, 5.0), (3, 2.0), (4, 1.5),
             (3, 0.5), (2, 1.0), (4, 0.8))


def _objectives(nf):
    return _OBJ_POOL[:nf]


def _data(rng, n):
    keys = rng.integers(0, 2**31 - 1, n).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    act = rng.random(n) > 0.07
    return keys, w, act


# ------------------------------------------------- kernel chain vs core path
@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("n", [1024, 1500])  # aligned and ragged
@pytest.mark.parametrize("nf", [1, 3, 8])
def test_batched_kernel_matches_core(rng, scheme, n, nf):
    keys, w, act = _data(rng, n)
    k = 16
    objs = _objectives(nf)
    m_k, p_k = multi_objective_bottomk_kernel(
        jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act), objs, k,
        scheme=scheme, seed=3)
    core = C.multi_bottomk_sample(
        keys, w, act, [(statfn_of(kind, prm), k) for kind, prm in objs],
        scheme=scheme, seed=3)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(core.member))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(core.prob))


def test_batched_kernel_matches_core_large_ragged(rng):
    keys, w, act = _data(rng, 3000)
    objs = _objectives(3)
    m_k, p_k = multi_objective_bottomk_kernel(
        jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act), objs, 33)
    core = C.multi_bottomk_sample(
        keys, w, act, [(statfn_of(kind, prm), 33) for kind, prm in objs])
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(core.member))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(core.prob))


def test_k_not_smaller_than_n(rng):
    """k >= n: every active key is a member with p = 1 (tau = +inf)."""
    keys, w, act = _data(rng, 600)
    m_k, p_k = multi_objective_bottomk_kernel(
        jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act),
        _objectives(2), 600)
    assert bool(jnp.all(m_k == jnp.asarray(act)))
    np.testing.assert_array_equal(np.asarray(p_k),
                                  np.where(act, 1.0, 0.0).astype(np.float32))


# ----------------------------------------------------- batched sub-primitives
@pytest.mark.parametrize("n,k", [(2048, 16), (3000, 33), (1000, 7)])
def test_batched_bottomk_select_matches_ref(rng, n, k):
    seeds = rng.exponential(1.0, (4, n)).astype(np.float32)
    seeds[rng.random((4, n)) > 0.9] = np.inf
    v, i, t = K.batched_bottomk_select(jnp.asarray(seeds), k)
    rv, ri, rt = R.batched_bottomk_select_ref(seeds, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(rt))


@pytest.mark.parametrize("n", [1024, 1500])
def test_fused_seeds_fvals_matches_ref(rng, n):
    keys, w, act = _data(rng, n)
    objs = _objectives(5)
    s, fv = K.fused_seeds_fvals(jnp.asarray(keys), jnp.asarray(w),
                                jnp.asarray(act), objs, seed=5)
    rs, rfv = R.fused_seeds_fvals_ref(keys, w, act, objs, seed=5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(rfv), rtol=1e-6)


# ------------------------------------------------------ launch-count flatness
def _count_pallas_calls(jaxpr):
    """Recursively count pallas_call eqns through nested (closed) jaxprs."""
    def subs(v):
        if hasattr(v, "jaxpr"):       # ClosedJaxpr
            return [v.jaxpr]
        if hasattr(v, "eqns"):        # Jaxpr
            return [v]
        if isinstance(v, (list, tuple)):
            return [s for x in v for s in subs(x)]
        return []

    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            count += 1
        for v in eqn.params.values():
            for sub in subs(v):
                count += _count_pallas_calls(sub)
    return count


@pytest.mark.parametrize("nf", [1, 3, 8])
def test_fused_path_launch_count_flat_in_F(nf):
    """ONE launch per kernel stage (seeds, block-select), regardless of |F|."""
    n, k = 2048, 16
    keys = jnp.arange(n, dtype=jnp.int32)
    w = jnp.ones((n,), jnp.float32)
    act = jnp.ones((n,), bool)
    objs = _objectives(nf)
    jx = jax.make_jaxpr(
        lambda ke, we, ac: multi_objective_bottomk_kernel(ke, we, ac, objs,
                                                          k))(keys, w, act)
    assert _count_pallas_calls(jx.jaxpr) == 2


def test_unknown_scheme_rejected():
    """A typo'd scheme must not silently mix priority seeds with ppswor
    probabilities."""
    keys = jnp.arange(64, dtype=jnp.int32)
    w = jnp.ones((64,), jnp.float32)
    act = jnp.ones((64,), bool)
    with pytest.raises(ValueError, match="scheme"):
        multi_objective_bottomk_kernel(keys, w, act, ((0, 0.0),), 8,
                                       scheme="bogus")


# ------------------------------------------------------------- satellites
def test_default_interpret_matches_backend():
    assert K.default_interpret() == (jax.default_backend() == "cpu")
    assert K.resolve_interpret(None) == K.default_interpret()
    assert K.resolve_interpret(True) is True
    assert K.resolve_interpret(False) is False


def test_rank_counts_ragged_n(rng):
    n = 700  # not a multiple of either block size
    w = rng.lognormal(0, 1.0, n).astype(np.float32)
    act = rng.random(n) > 0.07
    u = C.uniform01(np.arange(n, dtype=np.int32), 0)
    from repro.core.hashing import rank_of
    r = rank_of(u, "ppswor")
    rw = jnp.where(act, r / jnp.maximum(jnp.asarray(w), 1e-30), jnp.inf)
    h_k, l_k = K.rank_counts(jnp.where(act, w, 0), u, rw, act)
    h_r, l_r = R.rank_counts_ref(jnp.where(act, w, 0), u, rw, act)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


def test_sample_leaf_single_scan_fixed_slots(rng):
    """distopt wire format invariants (a fixed 3k-slot MultiSketch slab)."""
    from repro.distopt.compression import _merge_leaf, _sample_leaf
    n, k = 4096, 64
    g = (rng.standard_normal(n) * (rng.random(n) < 0.3)).astype(np.float32)
    sk = _sample_leaf(jnp.asarray(g), k, 7, 0.01)
    assert (sk.keys.shape == sk.weights.shape == sk.probs.shape
            == sk.valid.shape == (3 * k,))
    assert sk.seeds.shape == (3, 3 * k) and sk.taus.shape == (3,)
    assert bool(jnp.all((sk.probs > 0) & (sk.probs <= 1.0)))
    assert bool(jnp.all(jnp.where(
        sk.valid, jnp.asarray(g)[jnp.maximum(sk.keys, 0)] == sk.weights,
        True)))
    # members occupy a prefix of the slots; empty slots carry key -1
    valid = sk.valid
    first_invalid = int(jnp.argmin(valid)) if not bool(valid.all()) else 3 * k
    assert bool(jnp.all(~valid[first_invalid:]))
    assert bool(jnp.all(jnp.where(valid, sk.keys >= 0, sk.keys == -1)))
    # HT estimate is exact when every nonzero is sampled (k >= nnz)
    g_small = np.zeros(512, np.float32)
    g_small[:40] = rng.standard_normal(40).astype(np.float32)
    sk = _sample_leaf(jnp.asarray(g_small), 64, 3, 0.01)
    est = _merge_leaf(sk.keys[None], sk.weights[None], sk.probs[None],
                      sk.valid[None], 512, 1)
    np.testing.assert_allclose(np.asarray(est), g_small, atol=1e-5)
