"""End-to-end behaviour tests: training loop, checkpoint/restart,
compression, data pipeline, telemetry — the system working together."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, Loader, SyntheticCorpus
from repro.launch import steps as St
from repro.launch.mesh import make_host_mesh, mesh_context
from repro.models import model as Mod
from repro.optim import adamw
from repro.telemetry.stats import StatsCollector, TelemetryConfig
import repro.core as C


def _setup(arch="qwen2-1.5b", steps=60):
    cfg = get_smoke_config(arch)
    mesh = make_host_mesh()
    opt = adamw.OptConfig(total_steps=steps, warmup_steps=3, peak_lr=5e-3)
    return cfg, mesh, opt


def test_training_reduces_loss():
    cfg, mesh, opt = _setup()
    key = jax.random.PRNGKey(0)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        step, sh = St.make_train_step(cfg, opt, mesh, donate=False)
        state = jax.device_put(
            {"params": params, "opt": adamw.init_opt_state(params)}, sh)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                              cfg.vocab_size)}
        first = last = None
        for i in range(8):
            state, m = step(state, batch)
            first = first if first is not None else float(m["loss"])
            last = float(m["loss"])
    assert last < first * 0.7


def test_microbatch_equivalent_loss():
    cfg, mesh, opt = _setup()
    key = jax.random.PRNGKey(0)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                                              cfg.vocab_size)}
        outs = []
        for mb in (None, 2, 4):
            step, sh = St.make_train_step(cfg, opt, mesh, donate=False,
                                          microbatch=mb)
            st = jax.device_put(
                {"params": params, "opt": adamw.init_opt_state(params)}, sh)
            st, m = step(st, batch)
            outs.append(float(m["loss"]))
    assert abs(outs[0] - outs[1]) < 5e-2 and abs(outs[0] - outs[2]) < 5e-2


def test_checkpoint_save_restore_resume(tmp_path):
    cfg, mesh, opt = _setup()
    key = jax.random.PRNGKey(0)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        step, sh = St.make_train_step(cfg, opt, mesh, donate=False)
        state = jax.device_put(
            {"params": params, "opt": adamw.init_opt_state(params)}, sh)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                              cfg.vocab_size)}
        for i in range(3):
            state, m = step(state, batch)
        mgr.save(3, state, blocking=True)
        state, m4 = step(state, batch)  # step 4 result

        restored, rstep = mgr.restore_latest(state, sh)
        assert rstep == 3
        r2, m4b = step(restored, batch)
        assert abs(float(m4b["loss"]) - float(m4["loss"])) < 1e-4


def test_checkpoint_corruption_falls_back(tmp_path):
    cfg, mesh, opt = _setup()
    key = jax.random.PRNGKey(0)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        state = {"params": params, "opt": adamw.init_opt_state(params)}
        mgr.save(1, state, blocking=True)
        mgr.save(2, state, blocking=True)
    # corrupt the newest checkpoint
    d = os.path.join(str(tmp_path), "step_0000000002")
    victim = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef" * 8)
    restored, rstep = mgr.restore_latest(state)
    assert rstep == 1  # fell back to the previous intact checkpoint


def test_keep_k_pruning(tmp_path):
    cfg, mesh, opt = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    key = jax.random.PRNGKey(0)
    with mesh_context(mesh):
        params, _ = Mod.init_model(key, cfg)
        state = {"params": params, "opt": adamw.init_opt_state(params)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state, blocking=True)
    assert mgr.list_steps() == [3, 4]


def test_data_loader_deterministic_and_importance_unbiased():
    dcfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4,
                      n_docs=2000, seed=3)
    corpus = SyntheticCorpus(dcfg)
    l1 = Loader(corpus, dcfg)
    l2 = Loader(corpus, dcfg)
    b1, b2 = l1.batch(7), l2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # replayable
    assert not np.array_equal(l1.batch(8)["tokens"], b1["tokens"])

    li = Loader(corpus, dcfg, importance=True, k=64)
    assert len(li.pool) > 0
    # universal-sample corpus statistics match exact within CV bound
    keys = np.arange(dcfg.n_docs, dtype=np.int32)
    act = np.ones(dcfg.n_docs, bool)
    s = C.universal_monotone_sample(keys, corpus.weights, act, 64, seed=3)
    for f in [C.SUM, C.COUNT, C.thresh(1.0)]:
        est = float(C.estimate(f, corpus.weights, s.prob, s.member))
        ex = float(C.exact(f, corpus.weights, act))
        assert abs(est / ex - 1) < 4 / np.sqrt(63), f.name


def test_telemetry_streaming_queries():
    tel = StatsCollector(TelemetryConfig(k=32, capacity=512))
    rng = np.random.default_rng(0)
    all_w = []
    for step in range(10):
        w = rng.lognormal(0, 1, 100).astype(np.float32)
        keys = step * 1000 + np.arange(100)
        tel.absorb(keys, w)
        all_w.append(w)
    w = np.concatenate(all_w)
    est = tel.query(C.SUM)
    assert abs(est / w.sum() - 1) < 0.5  # k=32 -> CV ~ 0.18; 2.5+ sigma slack
    est_c = tel.query(C.COUNT)
    assert abs(est_c / 1000 - 1) < 0.5


def test_elastic_restart_reshards(tmp_path):
    """Checkpoint on one mesh restores onto a different mesh."""
    cfg, _, opt = _setup()
    key = jax.random.PRNGKey(0)
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    mgr = CheckpointManager(str(tmp_path))
    with mesh_context(mesh1):
        params, _ = Mod.init_model(key, cfg)
        state = {"params": params, "opt": adamw.init_opt_state(params)}
        mgr.save(5, state, blocking=True)
    mesh2 = make_host_mesh()  # possibly different shape
    with mesh_context(mesh2):
        step, sh = St.make_train_step(cfg, opt, mesh2, donate=False)
        restored, rstep = mgr.restore_latest(state, sh)
        assert rstep == 5
        batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                              cfg.vocab_size)}
        _, m = step(restored, batch)
        assert bool(jnp.isfinite(m["loss"]))
