"""Statistical-guarantee tier: the paper's CV bounds as seeded pytest.

Thm 3.1 / §5.1 promise: one multi-objective summary answers every f ∈ F
with the SAME per-objective CV guarantee as a dedicated bottom-k sample —
cv(Q^(f, H)) <= sqrt(1 / (q (k_f - 1))) with q = Q(f, H) / Q(f, X). The
benches eyeball this; serving needs it ENFORCED, so this module measures
many-trial estimator variance at fixed seeds (deterministic — the trials
are hash-seed replications through one vmapped executable, the
runtime-seed build path) and asserts the bound per objective, per scheme,
per |F| ∈ {1, 3, 8}.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.core.multi_sketch import _build_body

N, K, TRIALS = 1200, 32, 200
# the empirical CV of T trials estimates the true CV with relative
# standard error ~ 1/sqrt(2T); the bound applies to the TRUE CV, so the
# assertion allows that measurement noise (3 sigma) on top — COUNT/CAP sit
# exactly at the bound (the theorem's tight case) and would otherwise
# flicker on the noise
CV_NOISE = 1.0 + 3.0 / np.sqrt(2.0 * TRIALS)


def _pool():
    return [(C.SUM, K), (C.COUNT, K), (C.thresh(3.0), K), (C.cap(2.0), K),
            (C.moment(1.5), K), (C.thresh(0.8), K), (C.cap(5.0), K),
            (C.moment(0.7), K)]


def _data():
    rng = np.random.default_rng(42)
    keys = np.arange(N, dtype=np.int32)
    w = rng.lognormal(0, 1.5, N).astype(np.float32)
    return keys, w, np.ones(N, bool)


def _trial_estimates(spec, keys, w, act):
    """[trials, |F|] segment estimates: one vmapped seeded build (shared
    executable across trials — the runtime hash-seed override path) and
    one HT pass per objective over the stacked slabs."""
    jk, jw, ja = jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act)
    build = jax.jit(jax.vmap(
        lambda s: _build_body(jk, jw, ja, spec, False, seed=s)))
    sks = build(jnp.arange(TRIALS, dtype=jnp.int32))
    segm = sks.keys % 3 == 0                      # the queried segment H
    out = []
    for f, _ in spec.objectives:
        ht = jnp.where(sks.member & segm,
                       f(sks.weights) / jnp.maximum(sks.probs, 1e-30), 0.0)
        out.append(np.asarray(jnp.sum(ht, axis=1)))
    return np.stack(out, axis=1)


def _check_cv(spec, keys, w, act):
    seg = keys % 3 == 0
    ests = _trial_estimates(spec, keys, w, act)
    for i, (f, kf) in enumerate(spec.objectives):
        ex = float(C.exact(f, w, act, seg))
        q = ex / float(C.exact(f, w, act))
        cv = float(np.std(ests[:, i]) / ex)
        bound = C.cv_bound(q, kf) * CV_NOISE
        assert cv <= bound, (f"{spec.scheme} |F|={spec.nf} {f.name}: "
                             f"cv={cv:.3f} > bound={bound:.3f}")
        # unbiasedness (Eq. 5): the trial mean sits within the estimator's
        # own standard error of the exact value
        bias = abs(float(np.mean(ests[:, i])) - ex) / ex
        assert bias <= 3.0 * max(cv, 1e-3) / np.sqrt(TRIALS) + 1e-2, f.name


@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("nf", [3, 8])
def test_cv_within_bound_multiobjective(scheme, nf):
    """cv <= bound for every objective of a shared |F|-objective summary."""
    keys, w, act = _data()
    spec = C.MultiSketchSpec(objectives=tuple(_pool()[:nf]), scheme=scheme,
                             seed=0)
    _check_cv(spec, keys, w, act)


@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("kind", ["sum", "count", "thresh", "cap", "moment"])
def test_cv_within_bound_single_objective(scheme, kind):
    """|F| = 1: each StatFn family meets its dedicated-sample bound."""
    f = {"sum": C.SUM, "count": C.COUNT, "thresh": C.thresh(3.0),
         "cap": C.cap(2.0), "moment": C.moment(1.5)}[kind]
    keys, w, act = _data()
    spec = C.MultiSketchSpec(objectives=((f, K),), scheme=scheme, seed=0)
    _check_cv(spec, keys, w, act)


def test_multiobjective_cv_no_worse_than_dedicated():
    """Thm 3.1's other half: the shared summary's per-objective variance
    is NO WORSE than a dedicated sample's (p^(F) >= p^(f) slot-wise), so
    growing F must not degrade an objective already in it."""
    keys, w, act = _data()
    seg = keys % 3 == 0
    cvs = {}
    for nf in (1, 8):
        spec = C.MultiSketchSpec(objectives=tuple(_pool()[:nf]), scheme="ppswor",
                                 seed=0)
        ests = _trial_estimates(spec, keys, w, act)
        ex = float(C.exact(C.SUM, w, act, seg))
        cvs[nf] = float(np.std(ests[:, 0]) / ex)
    # same seeds, strictly more forgiving probabilities at |F|=8: allow
    # only trial noise (the estimators are not identical draws)
    assert cvs[8] <= cvs[1] * 1.25, cvs
