"""Paper §7: metric-domain universal samples (centrality + ball density)."""
import numpy as np
import pytest

from repro.core.metric_domains import (estimate_ball_density,
                                       estimate_centrality,
                                       universal_metric_sample)


@pytest.fixture
def points(rng):
    # clustered points: the interesting regime for anchor-based bounds
    centers = rng.normal(0, 5, (5, 3))
    return (centers[rng.integers(0, 5, 600)]
            + rng.normal(0, 0.7, (600, 3))).astype(np.float32)


def test_centrality_unbiased_for_many_queries(points, rng):
    k = 48
    queries = rng.normal(0, 5, (6, 3)).astype(np.float32)
    for q in queries:
        exact = float(np.sum(np.linalg.norm(points - q, axis=1)))
        ests = [float(estimate_centrality(
            universal_metric_sample(points, k, seed=s), points, q))
            for s in range(60)]
        assert abs(np.mean(ests) / exact - 1) < 0.1, q
        # gold-standard-style spread (overhead constant <= 2^mu)
        assert np.std(ests) / exact < 2.0 / np.sqrt(k - 1)


def test_ball_density_same_sample(points, rng):
    k = 48
    s = universal_metric_sample(points, k, seed=7)
    q = points[3] + 0.1
    for r in (1.0, 3.0, 8.0):
        exact = float(np.sum(np.linalg.norm(points - q, axis=1) <= r))
        if exact < 20:
            continue  # tiny segments: CV bound too loose to test tightly
        ests = [float(estimate_ball_density(
            universal_metric_sample(points, k, seed=i), points, q, r))
            for i in range(60)]
        assert abs(np.mean(ests) / exact - 1) < 0.25, r


def test_sample_size_overhead_constant(points):
    """§7: universality overhead is a constant factor over k (not |X|)."""
    for k in (16, 32):
        sizes = [int(universal_metric_sample(points, k, seed=s).member.sum())
                 for s in range(10)]
        assert np.mean(sizes) <= 2.5 * (2.0 ** 1.0) * k
