"""Fault-tolerance tier for the multi-tenant serving pool (launch.pool).

The acceptance contract: under injected device errors, corrupt chunks,
checkpoint corruption and torn WALs, the pool never crashes, never answers
silently wrong (every degraded response is labeled STALE/REJECTED with its
epoch lag), and crash recovery (restore + WAL replay) is BIT-IDENTICAL to
the uncrashed engine's merged slab.
"""
import os
import threading

import numpy as np
import pytest

import repro.core as C
from repro.core.multi_sketch import quarantine_chunk
from repro.launch.pool import (FRESH, REJECTED, STALE, CircuitBreaker,
                               EnginePool, RejectedError)
from repro.launch.query import SegmentQueryEngine
from repro.launch.wal import WriteAheadLog

from tests.faults import (FaultInjected, FaultInjector, corrupt_checkpoint,
                          poisson_arrivals, tear_wal)


def _spec(seed=0):
    return C.MultiSketchSpec(objectives=((C.SUM, 16), (C.COUNT, 8),
                                         (C.thresh(2.0), 12)), seed=seed)


def _chunks(n_chunks=6, n=160, seed=3):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_chunks):
        keys = (i * n + np.arange(n)).astype(np.int32)
        w = rng.lognormal(0, 1.5, n).astype(np.float32)
        out.append((keys, w))
    return out


def _fast_pool(**kw):
    """Pool with no real sleeping (backoff jitter via a no-op sleep)."""
    kw.setdefault("sleep", lambda s: None)
    kw.setdefault("backoff_base", 1e-4)
    return EnginePool(**kw)


def _assert_slabs_equal(a, b):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"leaf {name} diverged")


# ---------------------------------------------------------------------------
# admission & backpressure
# ---------------------------------------------------------------------------

def test_queue_full_sheds_load_with_rejected_error():
    pool = _fast_pool(queue_depth=4)
    pool.create_stream("t", _spec())
    futs = [pool.submit("t") for _ in range(4)]
    with pytest.raises(RejectedError):
        pool.submit("t")
    assert pool.pump() == 4
    assert all(f.result(1.0).status == FRESH for f in futs)
    # the queue drained -> admission open again
    pool.submit("t")
    assert pool.queue_len() == 1


def test_expired_deadline_is_rejected_not_silently_late():
    t = [0.0]
    pool = _fast_pool(clock=lambda: t[0])
    pool.create_stream("t", _spec())
    fut = pool.submit("t", timeout=0.5)
    t[0] = 1.0                      # deadline passes while queued
    pool.pump()
    r = fut.result(1.0)
    assert r.status == REJECTED and r.error == "deadline"
    assert r.values is None


def test_pump_coalesces_same_stream_queries_into_one_bucket(monkeypatch):
    pool = _fast_pool()
    spec = _spec()
    eng = pool.create_stream("t", spec)
    keys, w = _chunks(1)[0]
    pool.absorb("t", keys, w)
    preds = [C.key_range(i * 20, i * 20 + 19) for i in range(6)]
    want = eng.query_many(predicates=preds)   # oracle, uncoalesced

    calls = []
    orig = SegmentQueryEngine.query_many

    def spy(self, fs=None, predicates=C.EVERYTHING):
        calls.append(np.asarray(predicates).shape[0])
        return orig(self, fs, predicates)
    monkeypatch.setattr(SegmentQueryEngine, "query_many", spy)

    futs = [pool.submit("t", predicates=p) for p in preds]
    pool.pump()
    assert calls == [len(preds)]    # ONE fused B-bucket, not 6 launches
    got = np.concatenate([f.result(1.0).values for f in futs], axis=1)
    np.testing.assert_array_equal(got, want)


def test_absorb_backlog_bound_sheds_ingest():
    pool = _fast_pool(pending_limit=3, retries=0, breaker_threshold=1,
                      breaker_reset=1e9)
    pool.create_stream("t", _spec())
    chunks = _chunks(5)
    with FaultInjector() as inj:
        inj.fail_always("absorb_fold")
        for keys, w in chunks[:3]:
            pool.absorb("t", keys, w)       # durable-pending, not applied
        with pytest.raises(RejectedError):
            pool.absorb("t", *chunks[3])    # bounded memory: shed
    assert pool.stats("t")["pending"] == 3


# ---------------------------------------------------------------------------
# quarantine
# ---------------------------------------------------------------------------

def test_quarantine_chunk_rejects_per_row():
    keys = np.array([1, 2, 3, -4, 5, 6, 2 ** 40], np.int64)
    w = np.array([1.0, np.nan, np.inf, 2.0, -3.0, 4.0, 1.0], np.float64)
    k, ww, act, n_bad = quarantine_chunk(keys, w)
    assert n_bad == 5               # nan, inf, neg key, neg weight, big key
    np.testing.assert_array_equal(act, [True, False, False, False, False,
                                        True, False])
    assert k.dtype == np.int32 and ww.dtype == np.float32
    assert np.isfinite(ww).all() and (ww >= 0).all()


def test_one_bad_producer_cannot_poison_a_tenant_slab():
    pool = _fast_pool()
    spec = _spec()
    eng = pool.create_stream("t", spec)
    keys, w = _chunks(1)[0]
    poisoned_w = w.copy()
    poisoned_w[::7] = np.nan
    poisoned_w[3::7] = -1.0
    receipt = pool.absorb("t", keys, poisoned_w)
    bad = int(np.isnan(poisoned_w).sum() + (poisoned_w < 0).sum())
    assert receipt.quarantined == bad
    assert receipt.accepted == keys.size - bad
    assert pool.stats("t")["quarantined"] == bad
    # bit-identical to a fold of only the clean rows (inactive == padding)
    clean = ~(np.isnan(poisoned_w) | (poisoned_w < 0))
    twin = SegmentQueryEngine(spec)
    twin.absorb(np.where(clean, keys, -1),
                np.where(clean, poisoned_w, 0).astype(np.float32), clean)
    _assert_slabs_equal(eng.merged, twin.merged)
    r = pool.query("t")
    assert r.status == FRESH and np.isfinite(r.values).all()


def test_all_rows_quarantined_is_a_clean_noop():
    pool = _fast_pool()
    pool.create_stream("t", _spec())
    receipt = pool.absorb("t", np.arange(4), np.full(4, np.nan))
    assert receipt.accepted == 0 and receipt.quarantined == 4
    assert pool.stats("t")["ingest_seq"] == 0   # nothing ack'd, no WAL row


# ---------------------------------------------------------------------------
# retry / backoff / circuit breaker
# ---------------------------------------------------------------------------

def test_transient_fault_retried_to_success():
    pool = _fast_pool(retries=3)
    pool.create_stream("t", _spec())
    keys, w = _chunks(1)[0]
    with FaultInjector() as inj:
        inj.fail_next("absorb_fold", 2)
        receipt = pool.absorb("t", keys, w)
        assert inj.fired["absorb_fold"] == 2
    assert receipt.applied
    st = pool.stats("t")
    assert st["epoch_lag"] == 0 and not st["breaker_open"]


def test_backoff_is_exponential_with_jitter():
    delays = []
    pool = EnginePool(retries=3, backoff_base=0.01, backoff_cap=10.0,
                      sleep=delays.append)
    pool.create_stream("t", _spec())
    with FaultInjector() as inj:
        inj.fail_next("absorb_fold", 3)
        pool.absorb("t", *_chunks(1)[0])
    assert len(delays) == 3
    for i, d in enumerate(delays):
        base = 0.01 * (2 ** i)
        assert base * 0.5 <= d <= base * 1.5    # jittered exponential


def test_breaker_opens_after_threshold_and_half_open_probes():
    t = [0.0]
    br = CircuitBreaker(threshold=2, reset_after=1.0, clock=lambda: t[0])
    assert br.allow()
    br.record_failure()
    assert not br.is_open and br.allow()
    br.record_failure()
    assert br.is_open and not br.allow() and br.open_count == 1
    t[0] = 1.5
    assert br.allow()               # half-open probe window
    br.record_failure()             # probe fails -> re-opens, clock resets
    t[0] = 2.0
    assert not br.allow()
    t[0] = 3.0
    assert br.allow()
    br.record_success()
    assert not br.is_open and br.allow()


# ---------------------------------------------------------------------------
# graceful degradation ladder
# ---------------------------------------------------------------------------

def test_ladder_fresh_stale_rejected():
    pool = _fast_pool(retries=1, breaker_threshold=1, breaker_reset=1e9)
    pool.create_stream("t", _spec())
    chunks = _chunks(3)
    pool.absorb("t", *chunks[0])

    r = pool.query("t")                             # rung 1: FRESH
    assert r.status == FRESH and r.epoch_lag == 0 and not r.overflow
    fresh_vals = r.values

    with FaultInjector() as inj:
        inj.fail_always("query_merge")
        r2 = pool.query("t")                        # rung 2: STALE
        assert r2.status == STALE and r2.epoch_lag == 0
        assert r2.error is not None
        np.testing.assert_array_equal(r2.values, fresh_vals)

        # new data while degraded: ack'd + folded, but the served slab is
        # the last-good one -> the label must carry the exact lag
        pool.absorb("t", *chunks[1])
        pool.absorb("t", *chunks[2])
        r3 = pool.query("t")
        assert r3.status == STALE and r3.epoch_lag == 2
        np.testing.assert_array_equal(r3.values, fresh_vals)

    # fault healed: breaker is open but the reset window (1e9) never
    # elapses -> still STALE; a pool with a sane window recovers below
    r4 = pool.query("t")
    assert r4.status == STALE

    # rung 3: REJECTED — a stream that never answered has no last-good
    pool2 = _fast_pool(retries=0, breaker_threshold=1, breaker_reset=1e9)
    pool2.create_stream("u", _spec())
    pool2.absorb("u", *chunks[0])
    with FaultInjector() as inj:
        inj.fail_always("query_merge")
        r5 = pool2.query("u")
    assert r5.status == REJECTED and r5.values is None
    assert r5.error is not None


def test_breaker_recovery_returns_to_fresh():
    t = [0.0]
    pool = _fast_pool(retries=0, breaker_threshold=1, breaker_reset=1.0,
                      clock=lambda: t[0])
    pool.create_stream("t", _spec())
    pool.absorb("t", *_chunks(1)[0])
    pool.query("t")
    with FaultInjector() as inj:
        inj.fail_always("query_merge")
        assert pool.query("t").status == STALE
        assert pool.stats("t")["breaker_open"]
        # while open (inside the reset window) the fresh path is not even
        # attempted — the stale answer is immediate
        calls_before = inj.calls.get("query_merge", 0)
        assert pool.query("t").status == STALE
        assert inj.calls.get("query_merge", 0) == calls_before
        inj.heal("query_merge")
        t[0] = 2.0                   # past reset -> half-open probe
        r = pool.query("t")
    assert r.status == FRESH and r.epoch_lag == 0
    assert not pool.stats("t")["breaker_open"]


def test_failed_fold_downgrades_to_stale_with_lag_then_replays():
    pool = _fast_pool(retries=0, breaker_threshold=1, breaker_reset=0.0)
    spec = _spec()
    eng = pool.create_stream("t", spec)
    chunks = _chunks(4)
    pool.absorb("t", *chunks[0])
    assert pool.query("t").status == FRESH
    with FaultInjector() as inj:
        inj.fail_next("absorb_fold", 2)
        receipt = pool.absorb("t", *chunks[1])   # fold fails; WAL has it
        assert not receipt.applied
        r = pool.query("t")
        assert r.status == STALE and r.epoch_lag == 1
        # second fault consumes this absorb's drain attempt too: backlog
        # grows, every row still durable in the WAL
        pool.absorb("t", *chunks[2])
        assert pool.stats("t")["epoch_lag"] == 2
        # fault exhausted: next absorb replays the backlog IN ORDER
        pool.absorb("t", *chunks[3])
    assert pool.stats("t")["epoch_lag"] == 0
    r = pool.query("t")
    assert r.status == FRESH and r.epoch_lag == 0
    # the replayed engine matches a twin that never saw a fault
    twin = SegmentQueryEngine(spec)
    for keys, w in chunks[:4]:
        twin.absorb(keys, w)
    _assert_slabs_equal(eng.merged, twin.merged)


def test_overflow_flag_carried_in_responses():
    # undersized capacity: the slab saturates and every answer must say so
    spec = C.MultiSketchSpec(objectives=((C.SUM, 16), (C.COUNT, 8)),
                             capacity=8)
    pool = _fast_pool()
    pool.create_stream("t", spec)
    keys, w = _chunks(1, n=256)[0]
    pool.absorb("t", keys, w)
    r = pool.query("t")
    assert r.ok and r.overflow
    assert pool.stats("t")["merge_stats"]["overflow"] is True
    # a right-sized stream never raises the flag
    pool.create_stream("ok", _spec())
    pool.absorb("ok", keys, w)
    assert pool.query("ok").overflow is False


# ---------------------------------------------------------------------------
# durability: WAL + snapshots + crash recovery
# ---------------------------------------------------------------------------

def test_wal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "w.log")
    wal = WriteAheadLog(path)
    rng = np.random.default_rng(0)
    recs = []
    for seq in range(1, 6):
        k = rng.integers(0, 1 << 20, 32).astype(np.int32)
        w = rng.random(32).astype(np.float32)
        a = rng.random(32) < 0.9
        wal.append(seq, seq % 2, k, w, a)
        recs.append((seq, seq % 2, k, w, a))
    wal.close()
    got = list(WriteAheadLog(path).replay())
    assert [r.seq for r in got] == [1, 2, 3, 4, 5]
    for r, (seq, shard, k, w, a) in zip(got, recs):
        assert r.shard == shard
        np.testing.assert_array_equal(r.keys, k)
        np.testing.assert_array_equal(r.weights, w)
        np.testing.assert_array_equal(r.active, a)
    # torn final write: every COMPLETE record still replays
    tear_wal(path, drop_bytes=13)
    got = list(WriteAheadLog(path).replay())
    assert [r.seq for r in got] == [1, 2, 3, 4]
    # mid-file corruption: conservative stop at the broken frame
    with open(path, "r+b") as f:
        f.seek(40)
        f.write(b"\x00" * 8)
    assert [r.seq for r in WriteAheadLog(path).replay()] == []


def test_wal_prune_keeps_tail(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "w.log"))
    for seq in range(1, 8):
        wal.append(seq, 0, np.arange(4, dtype=np.int32),
                   np.ones(4, np.float32), np.ones(4, bool))
    wal.prune(4)
    assert [r.seq for r in wal.replay()] == [5, 6, 7]
    wal.append(8, 0, np.arange(4, dtype=np.int32),
               np.ones(4, np.float32), np.ones(4, bool))
    assert [r.seq for r in wal.replay()] == [5, 6, 7, 8]


def test_wal_prune_streams_frames_and_is_byte_identical(tmp_path):
    """Regression (multi-MB log): prune must copy surviving frames
    through VERBATIM — the post-prune file is byte-identical to a log
    that only ever contained the kept records — and must stream frame
    by frame, never materializing decoded records (no replay())."""
    rng = np.random.default_rng(0)
    n_frames, rows = 160, 4096            # ~5.6 MB of payload
    frames = []
    for seq in range(1, n_frames + 1):
        frames.append((seq, seq % 8,
                       rng.integers(0, 1 << 30, rows).astype(np.int32),
                       rng.random(rows).astype(np.float32),
                       rng.random(rows) < 0.9))
    path = str(tmp_path / "big.log")
    wal = WriteAheadLog(path, fsync=False)
    for f in frames:
        wal.append(*f)
    assert os.path.getsize(path) > 4 << 20
    cut = n_frames // 2
    # prune is a streaming frame copy: decoding records would be O(log)
    # memory, so replay() must never run underneath it
    real_replay = WriteAheadLog.replay
    WriteAheadLog.replay = lambda *a, **k: (_ for _ in ()).throw(
        AssertionError("prune materialized records via replay()"))
    try:
        wal.prune(cut)
    finally:
        WriteAheadLog.replay = real_replay
    wal.close()
    ref_path = str(tmp_path / "ref.log")
    ref = WriteAheadLog(ref_path, fsync=False)
    for f in frames[cut:]:
        ref.append(*f)
    ref.close()
    with open(path, "rb") as a, open(ref_path, "rb") as b:
        assert a.read() == b.read()       # bytes, not just records
    got = list(WriteAheadLog(path).replay())
    assert [r.seq for r in got] == list(range(cut + 1, n_frames + 1))


def test_wal_create_fsyncs_parent_directory(tmp_path, monkeypatch):
    """Regression: a freshly created WAL file must fsync its parent
    directory, or a crash can lose the FILE (and with it every durable=
    True ack) even though each append fsync'd the data."""
    import stat
    dir_syncs = []
    real_fsync = os.fsync

    def spy(fd):
        if stat.S_ISDIR(os.fstat(fd).st_mode):
            dir_syncs.append(fd)
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", spy)
    path = str(tmp_path / "w.log")
    wal = WriteAheadLog(path)             # creates the file
    assert len(dir_syncs) == 1
    wal.append(1, 0, np.arange(4, dtype=np.int32),
               np.ones(4, np.float32), np.ones(4, bool))
    wal.close()
    WriteAheadLog(path).close()           # reopen: no new entry to persist
    assert len(dir_syncs) == 1
    WriteAheadLog(str(tmp_path / "w2.log"), fsync=False).close()
    assert len(dir_syncs) == 1            # fsync=False opts out entirely


def test_wal_last_seq_cached_and_survives_append_and_prune(tmp_path):
    """Regression: last_seq() used to rescan the whole log on every
    absorb. It must scan at most once per open, track appends
    incrementally, and stay correct across prune."""
    path = str(tmp_path / "w.log")
    wal = WriteAheadLog(path)
    for seq in range(1, 6):
        wal.append(seq, 0, np.arange(4, dtype=np.int32),
                   np.ones(4, np.float32), np.ones(4, bool))
    wal.close()

    scans = []
    real_replay = WriteAheadLog.replay

    def spy(self, *a, **k):
        scans.append(1)
        return real_replay(self, *a, **k)

    WriteAheadLog.replay = spy
    try:
        wal = WriteAheadLog(path)         # existing log: seq unknown
        assert wal.last_seq() == 5 and len(scans) == 1
        assert wal.last_seq() == 5 and len(scans) == 1   # cached
        wal.append(6, 0, np.arange(4, dtype=np.int32),
                   np.ones(4, np.float32), np.ones(4, bool))
        assert wal.last_seq() == 6 and len(scans) == 1   # incremental
        wal.prune(3)                      # rewrite keeps the cache honest
        assert wal.last_seq() == 6 and len(scans) == 1
        wal.close()
    finally:
        WriteAheadLog.replay = real_replay
    # a NEW empty log never needs a scan at all
    scans.clear()
    WriteAheadLog.replay = spy
    try:
        w2 = WriteAheadLog(str(tmp_path / "new.log"))
        assert w2.last_seq() == 0 and not scans
        w2.close()
    finally:
        WriteAheadLog.replay = real_replay


def test_wal_append_after_close_raises_explicit_error(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "w.log"))
    wal.close()
    with pytest.raises(ValueError, match="closed WAL"):
        wal.append(1, 0, np.arange(4, dtype=np.int32),
                   np.ones(4, np.float32), np.ones(4, bool))


def test_zero_timeout_sheds_queries_and_admin_under_frozen_clock():
    """Regression: with deadline = now + 0 and a clock that does not
    advance between submit and pump, the old strict `>` check served a
    zero-budget request instead of shedding it. timeout=0 must be
    REJECTED/"deadline" for BOTH queries and admin ops."""
    t = [100.0]
    pool = _fast_pool(clock=lambda: t[0])
    pool.create_stream("t", _spec())
    for keys, w in _chunks(2):
        pool.absorb("t", keys, w)
    r = pool.query("t", timeout=0)
    assert r.status == REJECTED and r.error == "deadline"
    r = pool.gc("t", timeout=0)
    assert r.status == REJECTED and r.error == "deadline"
    # a real budget under the same frozen clock still serves
    assert pool.query("t", timeout=5.0).status == FRESH
    assert pool.compact("t", timeout=5.0).status == FRESH


def test_crash_recovery_bit_identical(tmp_path):
    chunks = _chunks(10)
    spec = _spec(seed=7)
    pool = _fast_pool(durability_dir=str(tmp_path / "pool"),
                      snapshot_every=4)
    eng = pool.create_stream("t", spec, shards=2)
    for i, (keys, w) in enumerate(chunks):
        pool.absorb("t", keys, w, shard=i % 2)
    live = eng.merged                # snapshots at seq 4 and 8; WAL to 10
    pool.close()                     # "crash": nothing flushed beyond WAL

    pool2 = EnginePool.open(str(tmp_path / "pool"))
    assert pool2.streams == ("t",)
    st = pool2.stats("t")
    assert st["ingest_seq"] == st["applied_seq"] == 10
    _assert_slabs_equal(pool2._streams["t"].engine.merged, live)
    r = pool2.query("t")
    assert r.status == FRESH and r.epoch_lag == 0


def test_recovery_survives_corrupt_newest_checkpoint(tmp_path):
    chunks = _chunks(10)
    pool = _fast_pool(durability_dir=str(tmp_path / "pool"),
                      snapshot_every=4)
    eng = pool.create_stream("t", _spec(), shards=2)
    for i, (keys, w) in enumerate(chunks):
        pool.absorb("t", keys, w, shard=i % 2)
    live = eng.merged
    pool.close()
    ckpt_dir = os.path.join(str(tmp_path / "pool"), "t", "ckpt")
    corrupt_checkpoint(ckpt_dir, "flip_byte")   # newest snapshot (seq 8)
    pool2 = EnginePool.open(str(tmp_path / "pool"))
    # fell back to the seq-4 snapshot, replayed WAL records 5..10
    _assert_slabs_equal(pool2._streams["t"].engine.merged, live)


def test_recovery_with_torn_wal_tail_keeps_complete_records(tmp_path):
    chunks = _chunks(5)
    spec = _spec()
    pool = _fast_pool(durability_dir=str(tmp_path / "pool"))
    pool.create_stream("t", spec)
    for keys, w in chunks:
        pool.absorb("t", keys, w)
    pool.close()
    tear_wal(os.path.join(str(tmp_path / "pool"), "t", "wal.log"), 11)
    pool2 = EnginePool.open(str(tmp_path / "pool"))
    assert pool2.stats("t")["applied_seq"] == 4   # last record torn away
    twin = SegmentQueryEngine(spec)
    for keys, w in chunks[:4]:
        twin.absorb(keys, w)
    _assert_slabs_equal(pool2._streams["t"].engine.merged, twin.merged)


def test_recovery_before_first_snapshot_is_pure_replay(tmp_path):
    chunks = _chunks(3)
    spec = _spec()
    pool = _fast_pool(durability_dir=str(tmp_path / "pool"),
                      snapshot_every=0)          # never snapshots
    eng = pool.create_stream("t", spec)
    for keys, w in chunks:
        pool.absorb("t", keys, w)
    live = eng.merged
    pool.close()
    pool2 = EnginePool.open(str(tmp_path / "pool"))
    _assert_slabs_equal(pool2._streams["t"].engine.merged, live)


def test_snapshot_failure_degrades_without_data_loss(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path / "pool"),
                      snapshot_every=2)
    eng = pool.create_stream("t", _spec())
    chunks = _chunks(4)
    with FaultInjector() as inj:
        inj.fail_always("ckpt_save")
        for keys, w in chunks:
            pool.absorb("t", keys, w)     # snapshots fail; ingest proceeds
    st = pool.stats("t")
    assert st["snapshot_failures"] >= 1 and st["epoch_lag"] == 0
    live = eng.merged
    pool.close()
    pool2 = EnginePool.open(str(tmp_path / "pool"))   # WAL-only recovery
    _assert_slabs_equal(pool2._streams["t"].engine.merged, live)


# ---------------------------------------------------------------------------
# background admission loop
# ---------------------------------------------------------------------------

def test_background_worker_serves_submissions():
    pool = _fast_pool()
    pool.create_stream("t", _spec())
    pool.absorb("t", *_chunks(1)[0])
    want = pool.query("t").values
    pool.start(interval=0.001)
    try:
        futs = [pool.submit("t") for _ in range(8)]
        got = [f.result(5.0) for f in futs]
    finally:
        pool.stop()
    for r in got:
        assert r.status == FRESH
        np.testing.assert_array_equal(r.values, want)


# ---------------------------------------------------------------------------
# chaos smoke: Poisson load + mixed fault schedule
# ---------------------------------------------------------------------------

def test_chaos_smoke_no_crashes_no_unlabeled_answers():
    rng = np.random.default_rng(42)
    pool = _fast_pool(queue_depth=64, retries=1, breaker_threshold=3,
                      breaker_reset=0.0)   # retries=1: ~16%/op exhausts
    # the schedule, so the ladder is actually exercised
    spec = _spec()
    fs = tuple(f for f, _ in spec.objectives)
    for name in ("a", "b"):
        pool.create_stream(name, spec)
        pool.absorb(name, *_chunks(1, seed=hash(name) % 100)[0])
        pool.query(name)                     # warm executables
    exact = {}
    for name in ("a", "b"):
        exact[name] = pool.query(name).values.copy()

    statuses = {FRESH: 0, STALE: 0, REJECTED: 0}
    n_req = 120
    with FaultInjector(seed=7) as inj:
        inj.fail_prob("query_merge", 0.4)
        inj.fail_prob("absorb_fold", 0.4)
        for i in range(n_req):
            name = "a" if rng.random() < 0.5 else "b"
            if i % 10 == 9:
                keys = (10_000 + i * 50 + np.arange(50)).astype(np.int32)
                w = rng.lognormal(0, 1, 50).astype(np.float32)
                w[::13] = np.nan             # corrupt producer rows
                try:
                    pool.absorb(name, keys, w)
                except RejectedError:
                    pass
            fut = pool.submit(name, fs)
            pool.pump()
            r = fut.result(5.0)
            statuses[r.status] += 1
            if r.ok:
                assert np.isfinite(r.values).all()
                if r.status == FRESH:
                    assert r.epoch_lag == 0
                else:
                    assert r.epoch_lag >= 0   # labeled degradation
    assert statuses[REJECTED] == 0            # last-good always available
    assert statuses[STALE] > 0                # the schedule did degrade us
    availability = (statuses[FRESH] + statuses[STALE]) / n_req
    assert availability >= 0.99
    # after the chaos window, one clean absorb replays any fold backlog
    # and streams converge back to FRESH
    for name in ("a", "b"):
        keys = (90_000 + np.arange(8)).astype(np.int32)
        pool.absorb(name, keys, np.ones(8, np.float32))
        r = pool.query(name)
        assert r.status == FRESH and r.epoch_lag == 0


def test_poisson_arrivals_shape():
    rng = np.random.default_rng(0)
    at = poisson_arrivals(100.0, 500, rng)
    assert at.shape == (500,) and np.all(np.diff(at) > 0)
    assert at[-1] == pytest.approx(5.0, rel=0.3)   # ~n/rate seconds


# ---------------------------------------------------------------------------
# checkpoint manager race (the satellite lock)
# ---------------------------------------------------------------------------

def test_async_save_prune_never_races_restore(tmp_path):
    from repro.ckpt.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state0 = {"a": np.full((64,), 0.0, np.float32)}
    mgr.save(0, state0)
    errors = []

    def writer():
        try:
            for step in range(1, 25):
                mgr.save(step, {"a": np.full((64,), float(step),
                                             np.float32)},
                         blocking=False)
        except Exception as e:   # pragma: no cover - the regression signal
            errors.append(e)
    th = threading.Thread(target=writer)
    th.start()
    try:
        for _ in range(50):
            state, step = mgr.restore_latest(
                {"a": np.zeros((64,), np.float32)})
            # prune may delete steps mid-iteration, but a returned state
            # must always be an INTACT step matching its own label
            assert state is not None
            np.testing.assert_array_equal(np.asarray(state["a"]),
                                          np.full((64,), float(step)))
    finally:
        th.join()
        mgr.wait()
    assert not errors
