"""Scale-out tier acceptance: multi-host pool vs the single-host oracle.

The contract under test (ISSUE 10 / core.merge failure-semantics):

  * the cross-host merged answer is BIT-IDENTICAL to a never-failed
    single-host union engine over the same records (threshold closure
    through per-host merged slabs, one shared fold family);
  * a host loss degrades reads to the replicated last-good slab at STALE
    (labeled, never wrong), absorbs to a durable pending backlog;
  * rebalance rebuilds a dead host's shards bit-exactly from checkpoint +
    WAL tail and logs the re-partition as a REBALANCE marker;
  * recovery replays data + GC + REBALANCE markers in seq order to the
    identical post-move layout; a LOST marker recovers the pre-move
    placement with bit-identical merged answers.
"""
import os

import jax
import numpy as np
import pytest

import repro.core as C
from repro.launch.cluster import ClusterEngine
from repro.launch.pool import (FRESH, REJECTED, STALE, HostDownError,
                               RejectedError, ShardedEnginePool,
                               compute_placement, rendezvous_owner)
from repro.launch.query import SegmentQueryEngine
from repro.launch.wal import REBALANCE_SHARD, WriteAheadLog
from repro.telemetry.stats import collect_host_gauges

from tests.faults import FaultInjector, tear_wal

HOSTS = (0, 1, 2, 3)
SHARDS = 16


@pytest.fixture(scope="module", autouse=True)
def _release_executables():
    # This module compiles a large family of per-host fold/merge/query
    # executables (4 full-width engines x many epochs) on top of an
    # already-long tier-1 run; on a small CI box the accumulated native
    # code arenas can crash a LATER module's compile. Drop them at
    # module teardown — later modules recompile what they need.
    yield
    jax.clear_caches()


def _spec(seed=0):
    return C.MultiSketchSpec(objectives=((C.SUM, 16), (C.COUNT, 8)),
                             seed=seed, capacity=128)


def _chunks(n_chunks=18, n=60, seed=3, shards=SHARDS):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_chunks):
        keys = (i * n + np.arange(n)).astype(np.int32)
        w = rng.lognormal(0, 1.5, n).astype(np.float32)
        out.append((int(rng.integers(0, shards)), keys, w))
    return out


def _fast_pool(**kw):
    kw.setdefault("hosts", HOSTS)
    kw.setdefault("sleep", lambda s: None)
    kw.setdefault("backoff_base", 1e-4)
    return ShardedEnginePool(**kw)


def _twin(chunks, spec=None, shards=SHARDS):
    """The never-failed single-host union oracle."""
    eng = SegmentQueryEngine(spec or _spec(), shards=shards)
    for sh, k, w in chunks:
        eng.absorb(k, w, shard=sh)
    return eng


def _assert_slabs_equal(a, b):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"leaf {name} diverged")


def _feed(pool, chunks, name="t"):
    for sh, k, w in chunks:
        pool.absorb(name, k, w, shard=sh)


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_rendezvous_placement_is_deterministic_and_total():
    p1 = compute_placement(SHARDS, HOSTS)
    p2 = compute_placement(SHARDS, list(reversed(HOSTS)))
    assert p1 == p2                        # order-free
    assert set(p1) <= set(HOSTS)
    # every host owns something at this shard:host ratio
    assert set(p1) == set(HOSTS)
    assert rendezvous_owner(0, (5,)) == 5
    with pytest.raises(ValueError):
        rendezvous_owner(0, ())


def test_rendezvous_movement_is_minimal_under_membership_change():
    base = compute_placement(64, HOSTS)
    # removing a host moves ONLY its shards
    down = compute_placement(64, (0, 1, 3))
    moved = [s for s in range(64) if base[s] != down[s]]
    assert moved and all(base[s] == 2 for s in moved)
    # adding a host only PULLS shards onto it
    up = compute_placement(64, HOSTS + (4,))
    moved = [s for s in range(64) if base[s] != up[s]]
    assert moved and all(up[s] == 4 for s in moved)


def test_absorb_fans_out_to_owner_hosts_only():
    pool = _fast_pool()
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks(10)
    _feed(pool, chunks)
    touched = {sh for sh, _, _ in chunks}
    for hid in HOSTS:
        eng = pool._hosts[hid].engines.get("t")
        owned = {s for s in touched if placement[s] == hid}
        if eng is None:
            assert not owned
            continue
        for s in range(SHARDS):
            assert eng.shard_live(s) == (s in owned)


# ---------------------------------------------------------------------------
# cross-host reads: exactness + caching
# ---------------------------------------------------------------------------

def test_query_bit_identical_to_single_host_union_engine():
    pool = _fast_pool()
    pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    twin = _twin(chunks)
    r = pool.query("t")
    assert r.status == FRESH and r.epoch_lag == 0
    np.testing.assert_array_equal(r.values, twin.query_many())
    preds = [C.key_range(0, 300), C.key_range(301, 10**6)]
    r2 = pool.query("t", predicates=preds)
    np.testing.assert_array_equal(r2.values,
                                  twin.query_many(predicates=preds))


def test_cross_host_merge_is_memoized_per_epoch():
    pool = _fast_pool()
    pool.create_stream("t", _spec(), shards=SHARDS)
    _feed(pool, _chunks(6))
    pool.query("t")
    st = pool._stream("t")
    merges = st.cross_merges
    assert merges >= 1
    for _ in range(5):
        assert pool.query("t").status == FRESH
    assert st.cross_merges == merges       # steady-state reads: zero merges
    sh, k, w = _chunks(1, seed=99)[0]
    pool.absorb("t", k, w, shard=sh)
    pool.query("t")
    assert st.cross_merges == merges + 1   # one re-selection per new epoch


def test_query_timeout_zero_is_rejected():
    t = [5.0]
    pool = _fast_pool(clock=lambda: t[0])
    pool.create_stream("t", _spec(), shards=4)
    r = pool.query("t", timeout=0)
    assert r.status == REJECTED and r.error == "deadline"
    assert pool.query("t", timeout=10.0).status == FRESH


# ---------------------------------------------------------------------------
# host loss: replica reads, pending backlog, follower promotion
# ---------------------------------------------------------------------------

def test_host_kill_serves_stale_from_replica_with_exact_lag():
    pool = _fast_pool()
    pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    good = pool.query("t")
    assert good.status == FRESH
    pool.kill_host(HOSTS[0])
    r = pool.query("t")
    assert r.status == STALE and r.error is not None
    np.testing.assert_array_equal(r.values, good.values)
    # lag counts chunks accepted after the replica was captured
    extra = _chunks(3, seed=11)
    for sh, k, w in extra:
        rec = pool.absorb("t", k, w, shard=sh)
        assert rec.seq > 0
    r2 = pool.query("t")
    assert r2.status == STALE and r2.epoch_lag >= len(extra)


def test_follower_promotion_survives_primary_replica_host_loss():
    pool = _fast_pool()
    pool.create_stream("t", _spec(), shards=SHARDS)
    _feed(pool, _chunks())
    good = pool.query("t")
    st = pool._stream("t")
    primary, follower = pool._replica_hosts(st)
    pool.kill_host(primary)               # replica + owned shards gone
    r = pool.query("t")
    assert r.status == STALE
    np.testing.assert_array_equal(r.values, good.values)
    assert st.name in pool._hosts[follower].replicas
    # losing the follower too wipes every replica -> REJECTED, labeled
    pool.kill_host(follower)
    r2 = pool.query("t")
    assert r2.status == REJECTED and r2.values is None
    assert r2.error is not None


def test_dead_owner_absorbs_stay_pending_durable_and_shed_at_limit(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path), pending_limit=4)
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    _feed(pool, _chunks(4))
    victim = placement[0]
    pool.kill_host(victim)
    dead_shard = placement.index(victim)
    k, w = np.arange(50, dtype=np.int32) + 10**6, np.ones(50, np.float32)
    for i in range(4):
        rec = pool.absorb("t", k + i * 50, w, shard=dead_shard)
        assert rec.durable and not rec.applied
    with pytest.raises(RejectedError):
        pool.absorb("t", k + 999, w, shard=dead_shard)
    s = pool.stats("t")
    assert s["pending"] == 4 and s["epoch_lag"] == 4
    assert not s["owners_alive"]


def test_fault_injector_kill_schedule_fires_at_exact_op_index():
    pool = _fast_pool()
    pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks(8)
    with FaultInjector() as inj:
        inj.kill_host(pool, HOSTS[1], at=5)
        for i, (sh, k, w) in enumerate(chunks):
            pool.absorb("t", k, w, shard=sh)
            if inj.calls.get("host_op", 0) <= 5:
                assert pool._hosts[HOSTS[1]].alive
        assert inj.fired["host_op"] == 1
    assert not pool._hosts[HOSTS[1]].alive


# ---------------------------------------------------------------------------
# rebalance: hand-off, dead-host rebuild, REBALANCE marker
# ---------------------------------------------------------------------------

def test_rebalance_after_kill_rebuilds_bit_identically(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path))
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    victim = placement[0]
    pool.kill_host(victim)
    extra = _chunks(4, seed=21)           # some land pending on the dead host
    for sh, k, w in extra:
        pool.absorb("t", k, w, shard=sh)
    out = pool.rebalance("t")["t"]
    assert out["error"] is None and out["moved"]
    assert all(o == victim for s, (o, n) in out["moved"].items())
    assert victim not in out["placement"]
    r = pool.query("t")
    twin = _twin(chunks + extra)
    assert r.status == FRESH and r.epoch_lag == 0
    np.testing.assert_array_equal(r.values, twin.query_many())
    # the re-partition was WAL-marked with the full placement
    recs = [rec for rec in pool._stream("t").wal.replay()
            if rec.shard == REBALANCE_SHARD]
    assert len(recs) == 1
    assert tuple(int(x) for x in recs[0].keys) == out["placement"]


def test_live_handoff_on_join_and_leave_is_bit_identical(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path))
    pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    twin = _twin(chunks)
    pool.host_join(9)
    out = pool.rebalance("t")["t"]
    assert out["moved"] and all(n == 9 for s, (o, n) in out["moved"].items())
    r = pool.query("t")
    assert r.status == FRESH
    np.testing.assert_array_equal(r.values, twin.query_many())
    # graceful decommission hands every shard back off the host
    pool.host_leave(9)
    assert 9 not in pool.hosts
    assert 9 not in pool.placement("t")
    r2 = pool.query("t")
    assert r2.status == FRESH
    np.testing.assert_array_equal(r2.values, twin.query_many())


def test_recovery_replays_rebalance_marker_to_identical_layout(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path))
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    pool.kill_host(placement[0])
    out = pool.rebalance("t")["t"]
    after = _chunks(3, seed=31)           # post-move records in the WAL
    for sh, k, w in after:
        pool.absorb("t", k, w, shard=sh)
    pool.close()
    pool2 = ShardedEnginePool.open(str(tmp_path), sleep=lambda s: None)
    assert pool2.placement("t") == out["placement"]
    twin = _twin(chunks + after)
    r = pool2.query("t")
    assert r.status == FRESH
    np.testing.assert_array_equal(r.values, twin.query_many())
    # per-host slabs landed on the replayed owners, bit-exactly
    st = pool2._stream("t")
    for s in range(SHARDS):
        hid = st.placement[s]
        eng = pool2._hosts[hid].engines.get("t")
        if eng is not None and eng.shard_live(s):
            _assert_slabs_equal(eng.shard_slab(s), twin._shards[s])
    pool2.close()


def test_lost_rebalance_marker_recovers_pre_move_placement(tmp_path):
    """The PR 7 lost-GC-marker contract, for REBALANCE: a marker that
    never became durable recovers the PRE-move placement — same union,
    bit-identical answers."""
    pool = _fast_pool(durability_dir=str(tmp_path))
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    twin = _twin(chunks)
    with FaultInjector() as inj:
        inj.fail_next("wal_append", 1)
        out = pool.rebalance("t", exclude=(placement[0],))["t"]
    assert out["moved"]
    assert out["error"] and "marker" in out["error"]
    pool.close()
    pool2 = ShardedEnginePool.open(str(tmp_path), sleep=lambda s: None)
    assert pool2.placement("t") == tuple(placement)   # pre-move layout
    r = pool2.query("t")
    assert r.status == FRESH
    np.testing.assert_array_equal(r.values, twin.query_many())
    pool2.close()


def test_torn_rebalance_marker_recovers_pre_move_placement(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path))
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks()
    _feed(pool, chunks)
    twin = _twin(chunks)
    out = pool.rebalance("t", exclude=(placement[0],))["t"]
    assert out["moved"] and out["error"] is None
    pool.close()
    # crash tore the marker frame mid-write
    tear_wal(str(tmp_path / "t" / "wal.log"), drop_bytes=7)
    pool2 = ShardedEnginePool.open(str(tmp_path), sleep=lambda s: None)
    assert pool2.placement("t") == tuple(placement)
    r = pool2.query("t")
    assert r.status == FRESH
    np.testing.assert_array_equal(r.values, twin.query_many())
    pool2.close()


def test_snapshot_plus_wal_tail_recovery_is_bit_identical(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path), snapshot_every=5,
                      keep_snapshots=2)
    pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks(17)
    _feed(pool, chunks)
    assert pool._stream("t").snapshot_seqs          # snapshots happened
    pool.close()
    pool2 = ShardedEnginePool.open(str(tmp_path), sleep=lambda s: None)
    r = pool2.query("t")
    assert r.status == FRESH
    np.testing.assert_array_equal(r.values, _twin(chunks).query_many())
    pool2.close()


def test_snapshot_refuses_while_an_owner_is_down(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path))
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    _feed(pool, _chunks(4))
    pool.kill_host(placement[0])
    with pytest.raises(HostDownError):
        pool.snapshot("t")


# ---------------------------------------------------------------------------
# availability smoke (the CI scaleout gate, mirrored in benchmarks/run.py)
# ---------------------------------------------------------------------------

def test_availability_smoke_host_kill_mid_stream(tmp_path):
    pool = _fast_pool(durability_dir=str(tmp_path), pending_limit=256)
    placement = pool.create_stream("t", _spec(), shards=SHARDS)
    chunks = _chunks(40, seed=7)
    twin = SegmentQueryEngine(_spec(), shards=SHARDS)
    statuses = {FRESH: 0, STALE: 0, REJECTED: 0}
    unlabeled = 0
    with FaultInjector() as inj:
        inj.kill_host(pool, placement[0], at=20)
        for sh, k, w in chunks:
            try:
                pool.absorb("t", k, w, shard=sh)
            except RejectedError:
                continue                   # shed ingest is not a read miss
            twin.absorb(k, w, shard=sh)
            r = pool.query("t")
            statuses[r.status] += 1
            if r.status == FRESH:
                # an unlabeled answer = FRESH that is not the exact truth
                if (r.epoch_lag != 0
                        or not np.array_equal(r.values, twin.query_many())):
                    unlabeled += 1
            elif r.status == STALE:
                if r.values is None or (r.epoch_lag == 0
                                        and r.error is None):
                    unlabeled += 1
    total = sum(statuses.values())
    availability = (statuses[FRESH] + statuses[STALE]) / total
    assert availability >= 0.99, statuses
    assert unlabeled == 0
    # post-recovery: rebalance, then answers match the never-failed twin
    pool.rebalance("t")
    r = pool.query("t")
    assert r.status == FRESH
    np.testing.assert_array_equal(r.values, twin.query_many())
    pool.close()


# ---------------------------------------------------------------------------
# per-host gauges + cluster-tier replica hand-off
# ---------------------------------------------------------------------------

def test_host_stats_and_telemetry_gauges():
    pool = _fast_pool()
    pool.create_stream("t", _spec(), shards=SHARDS)
    _feed(pool, _chunks(8))
    pool.query("t")
    g = collect_host_gauges(pool)
    assert set(g["hosts"]) == set(HOSTS)
    assert g["totals"]["hosts_alive"] == len(HOSTS)
    assert g["totals"]["owned_shards"] == SHARDS
    assert g["totals"]["live_shards"] >= 1
    assert g["totals"]["bytes_resident"] > 0
    assert g["totals"]["replica_streams"] == 2   # primary + follower
    pool.kill_host(HOSTS[0])
    g2 = collect_host_gauges(pool)
    assert g2["totals"]["hosts_alive"] == len(HOSTS) - 1
    assert not g2["hosts"][HOSTS[0]]["alive"]
    assert g2["hosts"][HOSTS[0]]["live_shards"] == 0


def test_cluster_engine_handoff_promotes_bit_identical_follower():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(400, 3)).astype(np.float32)
    src = ClusterEngine(dim=3, k=32, seed=5, chunk=64)
    src.absorb(X[:250])
    follower = ClusterEngine.from_handoff(src.handoff())
    from repro.core.costs import cost_query
    q = cost_query(X[:4], 2.0)
    np.testing.assert_array_equal(src.service_costs(q),
                                  follower.service_costs(q))
    # the frozen normalizers rode along: continued absorbs on both sides
    # stay sample-coordinated, bit for bit
    src.absorb(X[250:])
    follower.absorb(X[250:])
    np.testing.assert_array_equal(src.service_costs(q),
                                  follower.service_costs(q))
    _assert_slabs_equal(src._sketch, follower._sketch)
