"""Incremental + absorb-time merged-slab maintenance.

The lazy ladder (PR 5): the delta fold (``multisketch_absorb_into`` —
dirty shards folded into the cached merged slab, donated buffers) must be
BIT-IDENTICAL to the full stacked re-merge for any absorb history, across
schemes and |F|; an incremental epoch must dispatch the delta-fold
launches ONLY (no full ``merge_stacked``), the full path must stay
unchanged, and non-monotone mutations (set_shard / load_stacked) must
force the full path. Lazy-ladder tests pin ``absorb_time=False``.

Absorb-time maintenance (PR 7 default): every query under churn is a pure
cache hit — ZERO merge dispatches on the query path (asserted via
``tests.dispatch_spy``) — and the maintained slab is bit-identical to the
lazy full re-merge oracle. Plus the ClusterEngine twin: delta-aware
coords realignment bit-identical to the full candidate lookup.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.core.multi_sketch import MultiSketch, multisketch_absorb_into
from repro.launch import query as Q
from repro.launch.query import SegmentQueryEngine
from tests.dispatch_spy import spy_merge_dispatch
from tests.test_batched_multiobj import _count_pallas_calls


def _objectives(nf):
    pool = [(C.SUM, 16), (C.COUNT, 8), (C.thresh(2.0), 12), (C.cap(1.5), 8),
            (C.moment(1.5), 8), (C.thresh(0.5), 8), (C.cap(4.0), 8),
            (C.moment(0.5), 8)]
    return tuple(pool[:nf])


def _data(n=2500, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.permutation(np.arange(5, 5 + n)).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    return keys, w


def _assert_bitsame(a: MultiSketch, b: MultiSketch, msg=""):
    for name, x, y in zip(MultiSketch._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg}{name}")


def _twin_engines(spec, shards, keys, w):
    """(incremental-enabled, forced-full) LAZY engines over the same
    absorbs — absorb-time maintenance off, so the query-time ladder
    (hit / delta fold / full re-merge) is what's under test."""
    inc = SegmentQueryEngine(spec, shards=shards, absorb_time=False)
    full = SegmentQueryEngine(spec, shards=shards, max_delta=0,
                              absorb_time=False)
    for i in range(shards):
        for e in (inc, full):
            e.absorb(keys[i::shards], w[i::shards], shard=i)
    return inc, full


# ------------------------------------------------- bit-identity, all specs
@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("nf", [1, 3, 8])
def test_incremental_bitidentical_to_full(scheme, nf):
    keys, w = _data()
    spec = C.MultiSketchSpec(objectives=_objectives(nf), scheme=scheme,
                             seed=11)
    inc, full = _twin_engines(spec, 3, keys, w)
    _assert_bitsame(inc._materialize_merged(), full._materialize_merged())
    # churn epochs: single-dirty-shard absorbs, re-merged incrementally
    rng = np.random.default_rng(nf)
    for it in range(3):
        ek = np.arange(90_000 + 500 * it, 90_000 + 500 * it + 300)
        ew = rng.lognormal(0, 1, 300).astype(np.float32)
        inc.absorb(ek, ew, shard=it % 3)
        full.absorb(ek, ew, shard=it % 3)
        _assert_bitsame(inc._materialize_merged(),
                        full._materialize_merged(), msg=f"epoch {it}: ")
    assert inc.merge_stats["incremental"] == 3
    assert inc.merge_stats["full"] == 1            # only the initial merge
    assert full.merge_stats["incremental"] == 0


def test_multi_dirty_delta_stacked_and_padded():
    """2 and 3 dirty shards of 4 between queries: the stacked (power-of-two
    padded) delta fold still matches the full re-merge bit-for-bit."""
    keys, w = _data(n=3000, seed=7)
    spec = C.MultiSketchSpec(objectives=_objectives(3), seed=3)
    inc, full = _twin_engines(spec, 4, keys, w)
    _assert_bitsame(inc._materialize_merged(), full._materialize_merged())
    rng = np.random.default_rng(1)
    for ndirty in (2, 3):
        for j in range(ndirty):
            ek = np.arange(50_000 + 1000 * ndirty + 100 * j,
                           50_000 + 1000 * ndirty + 100 * j + 80)
            ew = rng.lognormal(0, 1, 80).astype(np.float32)
            inc.absorb(ek, ew, shard=j)
            full.absorb(ek, ew, shard=j)
        _assert_bitsame(inc._materialize_merged(),
                        full._materialize_merged(), msg=f"{ndirty} dirty: ")
    assert inc.merge_stats["incremental"] == 2


def test_add_shard_rides_delta_path():
    """Cross-job fan-in only ADDS data -> the new slab is the delta."""
    keys, w = _data(n=2000, seed=2)
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=9)
    inc, full = _twin_engines(spec, 2, keys, w)
    inc._materialize_merged(), full._materialize_merged()
    other = C.multisketch_build(spec, np.arange(70_000, 70_500),
                                np.ones(500, np.float32))
    inc.add_shard(other)
    full.add_shard(other)
    _assert_bitsame(inc._materialize_merged(), full._materialize_merged())
    assert inc.merge_stats["incremental"] == 1
    # and both equal the one-shot union build
    union = C.multisketch_merge(spec, C.multisketch_build(spec, keys, w),
                                other)
    _assert_bitsame(inc._materialize_merged(), union, msg="vs union: ")


def test_set_shard_and_load_stacked_force_full():
    """Non-monotone mutations (shard content replaced) void the delta
    fold's containment premise — the engine must take the full path."""
    keys, w = _data(n=1500, seed=4)
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=5)
    eng = SegmentQueryEngine(spec, shards=2)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    eng._materialize_merged()
    n_full = eng.merge_stats["full"]
    replacement = C.multisketch_build(spec, np.arange(40_000, 40_300),
                                      np.ones(300, np.float32))
    eng.set_shard(1, replacement)
    eng._materialize_merged()
    assert eng.merge_stats["full"] == n_full + 1
    assert eng.merge_stats["incremental"] == 0
    # result reflects the REPLACED union, exactly
    want = C.multisketch_merge(
        spec, C.multisketch_build(spec, keys[::2], w[::2]), replacement)
    _assert_bitsame(eng._materialize_merged(), want)
    # load_stacked likewise drops the cache
    stacked = MultiSketch(*jax.tree.map(
        lambda *xs: jnp.stack(xs), *[replacement, replacement]))
    eng.load_stacked(stacked)
    eng._materialize_merged()
    assert eng.merge_stats["incremental"] == 0


def test_truncating_capacity_skips_incremental():
    """A capacity below the hard |S ∪ Z| bound may truncate, where delta
    and full paths can legitimately diverge — incremental must not run."""
    objs = _objectives(2)
    spec = C.MultiSketchSpec(objectives=objs, seed=1, capacity=8)
    keys, w = _data(n=800, seed=6)
    eng = SegmentQueryEngine(spec, shards=2)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    eng._materialize_merged()
    eng.absorb(np.arange(60_000, 60_100), np.ones(100, np.float32), shard=0)
    eng._materialize_merged()
    assert eng.merge_stats["incremental"] == 0
    assert eng.merge_stats["full"] == 2


# ------------------------------------------------- launch / dispatch counts
def test_incremental_epoch_dispatches_delta_fold_only(monkeypatch):
    """Incremental epoch = the delta fold ONLY (no full merge_stacked
    dispatch); full-path epochs and cache hits stay unchanged."""
    keys, w = _data(n=1200, seed=8)
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=2)
    eng = SegmentQueryEngine(spec, shards=2, absorb_time=False)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    eng._materialize_merged()                      # initial full merge
    with spy_merge_dispatch() as calls:
        eng.absorb(np.arange(30_000, 30_200), np.ones(200, np.float32),
                   shard=1)
        eng.query_many()                           # incremental epoch
        assert calls == {"full": 0, "inc": 1}
        eng.query_many()                           # cache hit: no dispatch
        assert calls == {"full": 0, "inc": 1}
        assert eng.merge_stats["hit"] >= 1
        # forced-full twin: merge_stacked only, never the delta fold
        eng.max_delta = 0
        eng.absorb(np.arange(31_000, 31_200), np.ones(200, np.float32),
                   shard=0)
        eng.query_many()
        assert calls == {"full": 1, "inc": 1}


@pytest.mark.parametrize("m", [1, 2, 4])
def test_delta_fold_launch_count_flat_in_dirty_shards(m):
    """The kernel-path delta fold is a fixed 4-launch chain (fused seeds,
    block-select, retention-priority, compacting block-select) regardless
    of how many dirty slabs ride in the delta."""
    spec = C.MultiSketchSpec(objectives=_objectives(3), seed=0)
    keys, w = _data(n=900, seed=9)
    base = C.multisketch_build(spec, keys, w)
    parts = [C.multisketch_build(spec, np.arange(10_000 * (i + 1),
                                                 10_000 * (i + 1) + 200),
                                 np.ones(200, np.float32))
             for i in range(m)]
    delta = (parts[0] if m == 1 else
             MultiSketch(*jax.tree.map(lambda *xs: jnp.stack(xs), *parts)))
    dk = delta.keys.reshape(-1)
    dw = delta.weights.reshape(-1)
    dv = delta.valid.reshape(-1)
    from repro.core.multi_sketch import _rebuild

    def fold(sk, sw, sv, dk, dw, dv):
        return _rebuild(spec, jnp.concatenate([sk, dk]),
                        jnp.concatenate([sw, dw]),
                        jnp.concatenate([sv, dv]), use_kernels=True)
    jx = jax.make_jaxpr(fold)(base.keys, base.weights, base.valid,
                              dk, dw, dv)
    assert _count_pallas_calls(jx.jaxpr) == 4


def test_absorb_into_matches_merge_and_donates_state():
    """Direct core-level check: absorb_into == multisketch_merge, and the
    state argument's buffers are consumed (donated) on backends that
    support it while the delta slab stays usable."""
    spec = C.MultiSketchSpec(objectives=_objectives(3), seed=6)
    keys, w = _data(n=1000, seed=10)
    a = C.multisketch_build(spec, keys[:500], w[:500])
    b = C.multisketch_build(spec, keys[500:], w[500:])
    want = C.multisketch_merge(spec, a, b)
    state = jax.tree.map(jnp.copy, a)
    got = multisketch_absorb_into(state, b, spec=spec)
    _assert_bitsame(got, want)
    # the delta (a resident shard slab) must NOT be donated
    assert int(jnp.sum(b.valid)) > 0
    # kernel and XLA delta folds agree bit-for-bit
    state2 = jax.tree.map(jnp.copy, a)
    got_k = multisketch_absorb_into(state2, b, spec=spec, use_kernels=True)
    _assert_bitsame(got_k, want)


def test_merged_handle_survives_incremental_fold():
    """A handed-out merged slab must stay readable after the next epoch's
    delta fold (which donates only engine-owned buffers)."""
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=8)
    keys, w = _data(n=1000, seed=12)
    eng = SegmentQueryEngine(spec, shards=2, absorb_time=False)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    held = eng.merged                              # public handout
    snap = np.asarray(held.keys).copy()
    before = int(jnp.sum(held.member))
    eng.absorb(np.arange(20_000, 20_100), np.ones(100, np.float32), shard=0)
    assert eng._materialize_merged() is not held
    assert eng.merge_stats["incremental"] == 1
    assert int(jnp.sum(held.member)) == before     # not donated away
    np.testing.assert_array_equal(np.asarray(held.keys), snap)


# ------------------------------------------------- absorb-time maintenance
@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("nf", [1, 3])
def test_absorb_time_bitidentical_to_lazy_oracle(scheme, nf):
    """Absorb-time maintenance == the lazy full re-merge oracle, bit for
    bit, at every churn epoch — and the query path dispatches NOTHING."""
    keys, w = _data(n=2000, seed=13)
    spec = C.MultiSketchSpec(objectives=_objectives(nf), scheme=scheme,
                             seed=21)
    zm = SegmentQueryEngine(spec, shards=3)               # the default
    oracle = SegmentQueryEngine(spec, shards=3, max_delta=0,
                                absorb_time=False)
    for i in range(3):
        for e in (zm, oracle):
            e.absorb(keys[i::3], w[i::3], shard=i)
    # first query warms the cache (cold start takes the lazy ladder once)
    _assert_bitsame(zm._materialize_merged(), oracle._materialize_merged())
    rng = np.random.default_rng(nf)
    for it in range(4):
        ek = np.arange(80_000 + 400 * it, 80_000 + 400 * it + 250)
        ew = rng.lognormal(0, 1, 250).astype(np.float32)
        zm.absorb(ek, ew, shard=it % 3)
        oracle.absorb(ek, ew, shard=it % 3)
        with spy_merge_dispatch() as calls:
            got = zm._materialize_merged()
        assert calls == {"full": 0, "inc": 0}, f"epoch {it} dispatched"
        _assert_bitsame(got, oracle._materialize_merged(),
                        msg=f"epoch {it}: ")
    assert zm.merge_stats["absorb_time"] == 4
    assert zm.merge_stats["hit"] >= 4


def test_absorb_time_single_shard_realias_no_double_fold():
    """Single-shard engine: the maintained cache ALIASES the shard, so an
    absorb epoch folds the chunk ONCE (the shard fold is the merged-slab
    fold) and the next query is still a dispatch-free hit."""
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=14)
    keys, w = _data(n=900, seed=14)
    eng = SegmentQueryEngine(spec)                        # 1 shard
    eng.absorb(keys, w)
    eng._materialize_merged()                             # warm (alias)
    held = eng.merged                                     # public handout
    snap = np.asarray(held.keys).copy()
    eng.absorb(np.arange(70_000, 70_150), np.ones(150, np.float32))
    with spy_merge_dispatch() as calls:
        got = eng._materialize_merged()
    assert calls == {"full": 0, "inc": 0}
    assert got is eng._shards[0]                          # re-aliased
    np.testing.assert_array_equal(np.asarray(held.keys), snap)  # survived
    # oracle: one-shot build over the union
    want = C.multisketch_build(
        spec, np.concatenate([keys, np.arange(70_000, 70_150)]),
        np.concatenate([w, np.ones(150, np.float32)]))
    _assert_bitsame(got, want, msg="vs one-shot: ")


def test_absorb_time_add_shard_keeps_cache_current():
    """add_shard under a current cache folds the new slab at absorb time
    — next query hits, bit-identical to the eager union."""
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=15)
    keys, w = _data(n=1100, seed=15)
    eng = SegmentQueryEngine(spec, shards=2)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    eng._materialize_merged()
    other = C.multisketch_build(spec, np.arange(75_000, 75_400),
                                np.ones(400, np.float32))
    eng.add_shard(other)
    with spy_merge_dispatch() as calls:
        got = eng._materialize_merged()
    assert calls == {"full": 0, "inc": 0}
    union = C.multisketch_merge(spec, C.multisketch_build(spec, keys, w),
                                other)
    _assert_bitsame(got, union, msg="vs union: ")


def test_absorb_time_nonmonotone_falls_back_then_reseeds():
    """set_shard drops the cache (maintenance can't ride a replaced
    shard); the next query re-merges fully, and maintenance resumes from
    the re-seeded cache on the following absorb."""
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=16)
    keys, w = _data(n=800, seed=16)
    eng = SegmentQueryEngine(spec, shards=2)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    eng._materialize_merged()
    repl = C.multisketch_build(spec, np.arange(42_000, 42_200),
                               np.ones(200, np.float32))
    eng.set_shard(1, repl)
    n_at = eng.merge_stats["absorb_time"]
    eng._materialize_merged()                             # full re-merge
    assert eng.merge_stats["full"] >= 2
    eng.absorb(np.arange(43_000, 43_100), np.ones(100, np.float32), shard=0)
    assert eng.merge_stats["absorb_time"] == n_at + 1     # resumed
    with spy_merge_dispatch() as calls:
        got = eng._materialize_merged()
    assert calls == {"full": 0, "inc": 0}
    want = C.multisketch_merge(
        spec,
        C.multisketch_merge(spec,
                            C.multisketch_build(spec, keys[::2], w[::2]),
                            repl),
        C.multisketch_build(spec, np.arange(43_000, 43_100),
                            np.ones(100, np.float32)))
    _assert_bitsame(got, want, msg="vs union: ")


# ------------------------------------------------- cluster coords twin
def test_align_coords_delta_bit_identical():
    from repro.launch.cluster import _align_coords, _align_coords_delta
    rng = np.random.default_rng(3)
    cap, dim, chunk = 96, 4, 40
    pts = rng.normal(0, 2, (400, dim)).astype(np.float32)
    old_keys = np.full(cap, -1, np.int32)
    occ = rng.permutation(cap)[:60]
    old_keys[occ] = rng.choice(300, 60, replace=False)
    old_coords = np.where(old_keys[:, None] >= 0, pts[old_keys], 0.0)
    # chunk: half re-presented old keys (same coords), half new
    ck = np.concatenate([old_keys[occ[:20]],
                         np.arange(300, 300 + chunk - 20)]).astype(np.int32)
    cc = pts[ck].astype(np.float32)
    # new slab: a shuffle of old ∪ chunk keys plus empty slots
    new_keys = np.full(cap, -1, np.int32)
    pool = np.concatenate([old_keys[old_keys >= 0], ck])
    pick = rng.choice(pool, 80, replace=False)
    new_keys[rng.permutation(cap)[:80]] = pick
    want = _align_coords(jnp.asarray(new_keys),
                         jnp.concatenate([jnp.asarray(old_keys),
                                          jnp.asarray(ck)]),
                         jnp.concatenate([jnp.asarray(old_coords),
                                          jnp.asarray(cc)]))
    got = _align_coords_delta(jnp.asarray(new_keys), jnp.asarray(old_keys),
                              jnp.asarray(old_coords), jnp.asarray(ck),
                              jnp.asarray(cc))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cluster_engine_streaming_alignment_after_delta_path():
    """End-to-end: streamed absorbs keep every slab slot's coords equal to
    its key's true point under the delta realignment."""
    from repro.launch.cluster import ClusterEngine
    rng = np.random.default_rng(5)
    n, dim = 600, 3
    X = rng.normal(0, 3, (n, dim)).astype(np.float32)
    eng = ClusterEngine(dim=dim, k=24, seed=0, chunk=128)
    for s in range(0, n, 150):
        eng.absorb(X[s:s + 150])
    ks = np.asarray(eng._sketch.keys)
    vv = np.asarray(eng._sketch.valid)
    cs = np.asarray(eng._coords)
    sel = vv & (ks >= 0)
    np.testing.assert_allclose(cs[sel], X[ks[sel]], rtol=0, atol=0)
