"""Shard lifecycle tier: GC/rebalance correctness for the serving engine.

Acceptance (ISSUE 7): a GC merge (cold shards folded into the compacted
base slab) is BIT-IDENTICAL to keeping the shards separate — the union,
hence the merged slab and every query answer, never changes; long-running
churn holds live-shard count and device bytes at O(capacity) under the
auto water-mark; and crash recovery (checkpoint + WAL replay, including
the GC marker) lands in the identical post-GC state.
"""
import numpy as np
import pytest

import repro.core as C
from repro.core.multi_sketch import MultiSketch
from repro.launch.pool import FRESH, REJECTED, EnginePool
from repro.launch.query import SegmentQueryEngine

from tests.faults import FaultInjector


def _spec(seed=0, scheme="ppswor", nf=3):
    pool = [(C.SUM, 16), (C.COUNT, 8), (C.thresh(2.0), 12), (C.cap(1.5), 8),
            (C.moment(1.5), 8), (C.thresh(0.5), 8), (C.cap(4.0), 8),
            (C.moment(0.5), 8)]
    return C.MultiSketchSpec(objectives=tuple(pool[:nf]), scheme=scheme,
                             seed=seed)


def _chunks(n_chunks, n=120, seed=3, key_space=4000):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, key_space, n).astype(np.int32),
             rng.lognormal(0, 1.2, n).astype(np.float32))
            for _ in range(n_chunks)]


def _assert_bitsame(a: MultiSketch, b: MultiSketch, msg=""):
    for name, x, y in zip(MultiSketch._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg}{name}")


# ---------------------------------------------------------------------------
# GC merge == eager union (bit-identity across schemes and |F|)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("nf", [1, 3, 8])
def test_gc_merge_equals_eager_union(scheme, nf):
    """Folding cold shards into the base slab never changes the merged
    slab: bit-identical to the no-GC engine, any scheme, any |F|."""
    spec = _spec(seed=7, scheme=scheme, nf=nf)
    eng = SegmentQueryEngine(spec, shards=5, absorb_time=False)
    ora = SegmentQueryEngine(spec, shards=5, absorb_time=False)
    for i, (k, w) in enumerate(_chunks(10, seed=nf)):
        eng.absorb(k, w, shard=i % 5)
        ora.absorb(k, w, shard=i % 5)
    victims = eng.gc(max_live=2)
    assert victims, "water-mark 2 over 5 live shards must evict"
    assert eng.merge_stats["live_shards"] <= 2
    assert eng.merge_stats["gc_merges"] == 1
    _assert_bitsame(eng.merged, ora.merged, f"{scheme}/nf={nf}: ")


def test_gc_preserves_current_cache_and_later_folds():
    """A current merged cache survives the GC epoch (re-stamped, not
    re-merged), and post-GC absorbs keep the absorb-time path exact."""
    spec = _spec(seed=1)
    eng = SegmentQueryEngine(spec, shards=4, absorb_time=True)
    ora = SegmentQueryEngine(spec, shards=4, absorb_time=False, max_delta=0)
    chunks = _chunks(8, seed=11)
    for i, (k, w) in enumerate(chunks[:5]):
        eng.absorb(k, w, shard=i % 4)
        ora.absorb(k, w, shard=i % 4)
    _assert_bitsame(eng.merged, ora.merged)
    hits = eng.merge_stats["hit"]
    assert eng.gc(max_live=2)
    # cache stayed current across the GC epoch: next query is a hit
    _assert_bitsame(eng.merged, ora.merged, "post-gc: ")
    assert eng.merge_stats["hit"] == hits + 1
    for i, (k, w) in enumerate(chunks[5:]):
        eng.absorb(k, w, shard=i % 2)
        ora.absorb(k, w, shard=i % 2)
        _assert_bitsame(eng.merged, ora.merged, "post-gc absorb: ")
    assert eng.merge_stats["full"] <= 1  # only the pre-GC bootstrap merge


def test_longrun_churn_plateaus_at_water_mark():
    """Under the auto water-mark, live shards and resident bytes stop
    growing: O(capacity), not O(stream lifetime)."""
    spec = _spec(seed=2)
    eng = SegmentQueryEngine(spec, shards=6, absorb_time=True, gc_max_live=3)
    ora = SegmentQueryEngine(spec, shards=6, absorb_time=False, max_delta=0)
    bytes_track, live_track = [], []
    for i, (k, w) in enumerate(_chunks(30, seed=5)):
        sh = int(np.random.default_rng(100 + i).integers(0, 6))
        eng.absorb(k, w, shard=sh)
        ora.absorb(k, w, shard=sh)
        bytes_track.append(eng.merge_stats["bytes_resident"])
        live_track.append(eng.merge_stats["live_shards"])
    assert eng.merge_stats["gc_merges"] > 0
    assert max(live_track) <= 6           # never above construction layout
    assert all(lv <= 3 for lv in live_track[6:]), \
        "live shards must plateau at the water-mark after warmup"
    # resident bytes plateau: the second half never exceeds the first
    assert max(bytes_track[15:]) <= max(bytes_track[:15])
    _assert_bitsame(eng.merged, ora.merged, "after churn+gc: ")


def test_gc_plan_is_deterministic_and_age_ordered():
    spec = _spec(seed=3)
    eng = SegmentQueryEngine(spec, shards=5, absorb_time=False)
    for i, (k, w) in enumerate(_chunks(5, seed=9)):
        eng.absorb(k, w, shard=i)           # shard i last-touched at epoch i+1
    assert eng.gc_plan(max_live=2) == eng.gc_plan(max_live=2)
    # oldest non-base victims first until <= max_live shards stay live
    assert eng.gc_plan(max_live=2) == [1, 2, 3]
    assert eng.gc_plan(min_age=3) == [1]
    assert eng.gc_plan(max_live=99) == []


def test_spill_victims_then_restore_bitsame(tmp_path):
    """gc(spill_dir=...) persists victim slabs through ckpt.manager; a
    from_checkpoint over the spill directory restores them bit-exactly."""
    spec = _spec(seed=4)
    eng = SegmentQueryEngine(spec, shards=4, absorb_time=False)
    for i, (k, w) in enumerate(_chunks(6, seed=13)):
        eng.absorb(k, w, shard=i % 4)
    pre = [eng._shards[i] for i in range(4)]
    victims = eng.gc(max_live=2, spill_dir=str(tmp_path / "spill"))
    assert victims
    restored, meta = SegmentQueryEngine.from_checkpoint(
        str(tmp_path / "spill"), return_meta=True)
    assert meta["spilled_from"] == victims
    for j, v in enumerate(victims):
        _assert_bitsame(restored._shards[j], pre[v], f"spilled shard {v}: ")


# ---------------------------------------------------------------------------
# pool admin op (gc/compact on the admission loop) + durability
# ---------------------------------------------------------------------------

def _fast_pool(**kw):
    kw.setdefault("sleep", lambda s: None)
    kw.setdefault("backoff_base", 1e-4)
    return EnginePool(**kw)


def test_pool_gc_serves_queries_first_and_labels_gc_epoch():
    pool = _fast_pool()
    pool.create_stream("t", _spec(seed=5), shards=4)
    for i, (k, w) in enumerate(_chunks(6, seed=17)):
        pool.absorb("t", k, w, shard=i % 4)
    q = pool.submit("t")
    g = pool.request_gc("t", max_live=2)
    pool.pump()
    rq, rg = q.result(1.0), g.result(1.0)
    # the query rode the same pump as the admin op and was served first,
    # against the pre-GC (identical-union) state
    assert rq.status == FRESH and not rq.gc_epoch
    assert rg.status == FRESH and rg.gc_epoch and len(rg.gc_victims) >= 1
    # responses served while the newest epoch is a GC epoch are labeled
    r2 = pool.query("t")
    assert r2.status == FRESH and r2.gc_epoch
    assert pool.stats("t")["gc_epoch"]
    # the label clears on the next data epoch
    k, w = _chunks(1, seed=18)[0]
    pool.absorb("t", k, w, shard=0)
    assert not pool.query("t").gc_epoch


def test_pool_gc_deadline_expires_to_rejected():
    t = [0.0]
    pool = _fast_pool(clock=lambda: t[0])
    pool.create_stream("t", _spec(seed=5), shards=2)
    fut = pool.request_gc("t", max_live=1, timeout=0.5)
    t[0] = 1.0
    pool.pump()
    r = fut.result(1.0)
    assert r.status == REJECTED and r.error == "deadline"


def test_pool_compact_merges_everything():
    pool = _fast_pool()
    pool.create_stream("t", _spec(seed=6), shards=4)
    for i, (k, w) in enumerate(_chunks(5, seed=19)):
        pool.absorb("t", k, w, shard=i % 4)
    r = pool.compact("t")
    assert r.ok and r.gc_victims
    assert pool._stream("t").engine.merge_stats["live_shards"] == 1


def test_crash_recovery_lands_in_identical_post_gc_state(tmp_path):
    """Checkpoint + WAL replay (data records AND the GC marker) reproduces
    the uncrashed engine's post-GC state bit-identically: every shard
    slab, the shard liveness layout, and the merged slab."""
    spec = _spec(seed=8)
    chunks = _chunks(9, seed=23)
    pool = _fast_pool(durability_dir=str(tmp_path), snapshot_every=4)
    pool.create_stream("t", spec, shards=4, absorb_time=True, gc_max_live=3)
    for i, (k, w) in enumerate(chunks[:6]):
        pool.absorb("t", k, w, shard=i % 4)
    assert pool.gc("t", max_live=2).ok
    for i, (k, w) in enumerate(chunks[6:]):
        pool.absorb("t", k, w, shard=i % 2)
    live = pool._stream("t").engine
    pool.close()

    pool2 = EnginePool.open(str(tmp_path), sleep=lambda s: None)
    rec = pool2._stream("t").engine
    assert len(rec._shards) == len(live._shards)
    assert rec._shard_live == live._shard_live
    for i in range(len(live._shards)):
        _assert_bitsame(rec._shards[i], live._shards[i], f"shard {i}: ")
    _assert_bitsame(rec.merged, live.merged, "merged: ")
    assert rec.merge_stats["live_shards"] == live.merge_stats["live_shards"]
    pool2.close()


def test_lost_gc_marker_keeps_answers_identical(tmp_path):
    """Apply-then-append: if the crash eats the GC marker, recovery
    replays into the pre-GC shard layout — whose merged slab (the union)
    is still bit-identical, so no answer ever changes."""
    spec = _spec(seed=9)
    chunks = _chunks(6, seed=29)
    pool = _fast_pool(durability_dir=str(tmp_path))
    pool.create_stream("t", spec, shards=4)
    for i, (k, w) in enumerate(chunks):
        pool.absorb("t", k, w, shard=i % 4)
    with FaultInjector().fail_next("wal_append", 1) as inj:
        r = pool.gc("t", max_live=2)
    assert inj.fired.get("wal_append", 0) == 1
    assert r.ok and r.gc_victims      # GC applied...
    assert r.error and "marker" in r.error  # ...but the directive was lost
    live_merged = pool._stream("t").engine.merged
    pool.close()

    pool2 = EnginePool.open(str(tmp_path), sleep=lambda s: None)
    rec = pool2._stream("t").engine
    # pre-GC layout (no marker to replay) — all four shards still live
    assert rec.merge_stats["live_shards"] == 4
    _assert_bitsame(rec.merged, live_merged, "merged after lost marker: ")
    pool2.close()
