"""Fault-injection chaos harness for the serving tier.

Shared by the fault-tolerance test tier (tests/test_serving_faults.py) and
the ``serving_chaos`` benchmark (benchmarks/run.py). Three layers:

  * :class:`FaultInjector` — deterministic failure schedules installed on
    the ``EnginePool`` fault points (launch.pool.FAULT_POINTS): fail the
    next N calls, fail forever, fail specific call indices, or fail with
    seeded probability — per point, optionally per stream — plus
    ``kill_host`` schedules that drop a scale-out host at an exact
    ``host_op`` call index (the machine-loss fault, tests/test_pool_
    scaleout.py);
  * corruption generators — :func:`corrupt_checkpoint` (the 5-mode
    checkpoint damage matrix) and :func:`tear_wal` (torn final write);
  * :func:`poisson_arrivals` — the open-loop load generator (latency is
    measured from the SCHEDULED arrival, so queueing delay under overload
    is charged to the server, not hidden by closed-loop self-throttling).
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from repro.launch import pool as pool_mod


class FaultInjected(RuntimeError):
    """The injected failure (stands in for a device error / IO fault)."""


class FaultInjector:
    """Deterministic fault schedules on the pool's named fault points.

    Use as a context manager; hooks are installed on ``__enter__`` and
    cleared on ``__exit__``. ``calls``/``fired`` count per-point activity
    so tests can assert a fault actually exercised the path it targeted.
    """

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._plans: Dict[str, dict] = {}
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    # -- schedule builders (chainable) --------------------------------------
    def fail_next(self, point: str, n: int = 1,
                  stream: Optional[str] = None,
                  exc: type = FaultInjected) -> "FaultInjector":
        """Fail the next ``n`` matching calls, then heal (transient)."""
        self._plans[point] = {"kind": "next", "n": int(n), "stream": stream,
                              "exc": exc}
        return self

    def fail_always(self, point: str, stream: Optional[str] = None,
                    exc: type = FaultInjected) -> "FaultInjector":
        """Fail every matching call until healed (persistent outage)."""
        self._plans[point] = {"kind": "always", "stream": stream, "exc": exc}
        return self

    def fail_calls(self, point: str, indices,
                   stream: Optional[str] = None,
                   exc: type = FaultInjected) -> "FaultInjector":
        """Fail the i-th matching calls (0-based) — scripted bursts."""
        self._plans[point] = {"kind": "calls", "set": set(map(int, indices)),
                              "stream": stream, "exc": exc}
        return self

    def fail_prob(self, point: str, p: float,
                  stream: Optional[str] = None,
                  exc: type = FaultInjected) -> "FaultInjector":
        """Fail each matching call with seeded probability ``p``."""
        self._plans[point] = {"kind": "prob", "p": float(p),
                              "stream": stream, "exc": exc}
        return self

    def heal(self, point: str) -> "FaultInjector":
        """Clear the schedule for one point (fault repaired mid-run) —
        the installed hook stays but its plan lookup now finds nothing."""
        self._plans.pop(point, None)
        return self

    def kill_host(self, pool, hid: int, at: int = 0,
                  point: str = "host_op",
                  stream: Optional[str] = None) -> "FaultInjector":
        """Kill one scale-out host at the ``at``-th matching call of
        ``point`` (0-based) — the deterministic host-loss schedule.
        Unlike the failure kinds this does NOT raise: it calls
        ``pool.kill_host(hid)`` and lets the interrupted operation fail
        (or survive) exactly as a real machine loss would — the pool sees
        ``HostDownError`` / pending backlog, never a synthetic exception.
        One-shot: later matching calls are no-ops."""
        self._plans[point] = {"kind": "kill", "pool": pool, "hid": int(hid),
                              "at": int(at), "stream": stream}
        return self

    # -- hook plumbing -------------------------------------------------------
    def _hook(self, point: str):
        def fire(stream: str):
            self.calls[point] = self.calls.get(point, 0) + 1
            plan = self._plans.get(point)
            if plan is None:
                return
            if (plan["stream"] is not None
                    and plan["stream"] != stream
                    # host_op labels are "<stream>@h<hid>" — match on the
                    # stream half so schedules can target one tenant
                    and plan["stream"] != stream.split("@")[0]):
                return
            idx = self.calls[point] - 1
            kind = plan["kind"]
            if kind == "kill":
                if idx == plan["at"]:
                    self.fired[point] = self.fired.get(point, 0) + 1
                    plan["pool"].kill_host(plan["hid"])
                return
            hit = (kind == "always"
                   or (kind == "next" and plan["n"] > 0)
                   or (kind == "calls" and idx in plan["set"])
                   or (kind == "prob" and self._rng.random() < plan["p"]))
            if not hit:
                return
            if kind == "next":
                plan["n"] -= 1
            self.fired[point] = self.fired.get(point, 0) + 1
            raise plan["exc"](f"injected {point} fault "
                              f"(stream={stream}, call={idx})")
        return fire

    def __enter__(self) -> "FaultInjector":
        for point in pool_mod.FAULT_POINTS:
            pool_mod.install_fault_hook(point, self._hook(point))
        return self

    def __exit__(self, *exc_info):
        pool_mod.clear_fault_hooks()
        return False


# ---------------------------------------------------------------------------
# corruption generators
# ---------------------------------------------------------------------------

CKPT_CORRUPTIONS = ("flip_byte", "truncate_array", "delete_meta",
                    "tmp_dir", "delete_array")


def _step_dirs(directory: str):
    return sorted(d for d in os.listdir(directory)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def corrupt_checkpoint(directory: str, mode: str,
                       step_dir: Optional[str] = None) -> str:
    """Damage the newest (or given) checkpoint step under ``directory``.

    Modes (the corruption matrix): ``flip_byte`` (crc must catch),
    ``truncate_array`` (short read), ``delete_meta`` (no manifest),
    ``tmp_dir`` (leftover partial step_N.tmp from a crashed save — must be
    IGNORED, the intact steps still restore), ``delete_array`` (partial
    checkpoint, an array file missing). Returns the path touched.
    """
    if mode not in CKPT_CORRUPTIONS:
        raise ValueError(f"unknown corruption mode {mode!r}")
    steps = _step_dirs(directory)
    target = os.path.join(directory, step_dir or steps[-1])
    if mode == "tmp_dir":
        tmp = os.path.join(directory, "step_9999999999.tmp")
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "half_written.npy"), "wb") as f:
            f.write(b"\x93NUMPY partial")
        return tmp
    npys = sorted(p for p in os.listdir(target) if p.endswith(".npy"))
    if mode == "flip_byte":
        path = os.path.join(target, npys[0])
        with open(path, "r+b") as f:
            f.seek(max(os.path.getsize(path) // 2, 80))  # data, not header
            b = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([b[0] ^ 0xFF]))
        return path
    if mode == "truncate_array":
        path = os.path.join(target, npys[0])
        with open(path, "r+b") as f:
            f.truncate(max(os.path.getsize(path) // 2, 1))
        return path
    if mode == "delete_meta":
        path = os.path.join(target, "meta.json")
        os.remove(path)
        return path
    path = os.path.join(target, npys[0])   # delete_array
    os.remove(path)
    return path


def tear_wal(path: str, drop_bytes: int = 7) -> int:
    """Tear the WAL's final record (crash mid-write): truncate the last
    ``drop_bytes`` bytes. Returns the new size."""
    size = os.path.getsize(path)
    new = max(size - int(drop_bytes), 0)
    with open(path, "r+b") as f:
        f.truncate(new)
    return new


# ---------------------------------------------------------------------------
# open-loop load generator
# ---------------------------------------------------------------------------

def poisson_arrivals(rate_hz: float, n: int,
                     rng: np.random.Generator) -> np.ndarray:
    """``n`` open-loop Poisson arrival times (seconds from start) at
    ``rate_hz`` — exponential inter-arrivals, cumulative."""
    gaps = rng.exponential(1.0 / float(rate_hz), int(n))
    return np.cumsum(gaps)
