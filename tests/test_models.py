"""Per-arch smoke tests + numerics of the model substrate."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config, get_config, list_archs
from repro.configs.shapes import SHAPES, cell_is_runnable
from repro.models import layers as L
from repro.models import model as Mod


def make_smoke_batch(cfg, key, B=2, S=32):
    if cfg.family == "encoder":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        P = cfg.frontend_tokens
        return {"tokens": jax.random.randint(key, (B, S - P), 0,
                                             cfg.vocab_size),
                "patches": jax.random.normal(key, (B, P, cfg.d_model))}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step; shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params, specs = Mod.init_model(key, cfg)
    batch = make_smoke_batch(cfg, key)
    loss, metrics = Mod.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: Mod.loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_smoke_config(a).family
                                  not in ("encoder", "vlm")])
def test_smoke_decode_consistency(arch):
    """Sequential decode == full forward logits (f32). VLM is excluded:
    patch embeddings only enter through prefill, which is covered by
    test_prefill_then_decode below."""
    old = Mod.ACT_DTYPE
    Mod.ACT_DTYPE = jnp.float32
    try:
        import dataclasses
        cfg = get_smoke_config(arch)
        if cfg.family == "moe":
            # avoid capacity-policy token drops: full-forward drops when an
            # expert overflows, decode (1 token/step) never does — that
            # difference is intended behaviour, not an inconsistency
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        key = jax.random.PRNGKey(1)
        params, _ = Mod.init_model(key, cfg)
        # combined seq (tokens + patches for vlm) must divide attn_chunk
        S = 16 if cfg.family != "vlm" else 32 - cfg.frontend_tokens
        tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(key, (2, cfg.frontend_tokens,
                                                       cfg.d_model))
        full = Mod.forward_logits(params, cfg, batch)
        cache = Mod.make_cache(cfg, 2, S + cfg.frontend_tokens
                               if cfg.family == "vlm" else S,
                               dtype=jnp.float32)
        off = cfg.frontend_tokens if cfg.family == "vlm" else 0
        errs = []
        for t in range(S):
            logits, cache = Mod.serve_step(params, cfg, tokens[:, t], cache,
                                           jnp.int32(off + t))
            # compare only the real-vocab logits at matching position
            pos = off + t
            errs.append(float(jnp.max(jnp.abs(
                logits[:, :cfg.vocab_size] - full[:, pos, :cfg.vocab_size]))))
        scale = float(jnp.abs(full[..., :cfg.vocab_size]).max())
        tol = 2e-2 if cfg.family == "moe" else 5e-3
        assert max(errs) <= tol * max(scale, 1.0), (max(errs), scale)
    finally:
        Mod.ACT_DTYPE = old


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_smoke_config(a).family != "encoder"])
def test_prefill_then_decode(arch):
    old = Mod.ACT_DTYPE
    Mod.ACT_DTYPE = jnp.float32
    try:
        cfg = get_smoke_config(arch)
        key = jax.random.PRNGKey(2)
        params, _ = Mod.init_model(key, cfg)
        tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
        batch = {"tokens": tokens}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(key, (2, cfg.frontend_tokens,
                                                       cfg.d_model))
        logits, cache = Mod.prefill(params, cfg, batch)
        fb = Mod.forward_logits(params, cfg, batch)
        err = float(jnp.max(jnp.abs(logits[:, :cfg.vocab_size]
                                    - fb[:, -1, :cfg.vocab_size])))
        scale = float(jnp.abs(fb[..., :cfg.vocab_size]).max())
        assert err <= 5e-3 * max(scale, 1.0)
    finally:
        Mod.ACT_DTYPE = old


def test_flash_attention_grad_matches_naive():
    B, S, H, K, hd = 2, 64, 4, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))

    def naive(q, k, v, causal):
        G = H // K
        qn = q.reshape(B, S, K, G, hd)
        s = jnp.einsum("bqkgh,bckh->bqkgc", qn, k) / np.sqrt(hd)
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, -1)
        return jnp.einsum("bqkgc,bckh->bqkgh", p, v).reshape(B, S, H, hd)

    for causal in (True, False):
        out = L.chunked_attention(q, k, v, causal=causal, chunk=16)
        ref = naive(q, k, v, causal)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5
        g1 = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(
            L.chunked_attention(q, k, v, causal=causal, chunk=16))),
            argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(naive(q, k, v, causal))),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            assert float(jnp.max(jnp.abs(a - b))) < 2e-5


def test_mamba_chunked_equals_sequential():
    from repro.models import mamba as M
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      vocab_size=64, ssm_kind="mamba1", ssm_state=4,
                      ssm_chunk=8)
    key = jax.random.PRNGKey(0)
    p, _ = M.init_mamba1(key, cfg)
    x = 0.1 * jax.random.normal(key, (2, 32, 32), jnp.float32)
    y_full, _ = M.apply_mamba1(p, x, cfg)
    st = M.mamba1_state(cfg, 2, jnp.float32)
    ys = []
    for t in range(32):
        yt, st = M.apply_mamba1(p, x[:, t:t + 1], cfg, state=st)
        ys.append(yt)
    assert float(jnp.max(jnp.abs(y_full - jnp.concatenate(ys, 1)))) < 1e-4


def test_full_configs_match_spec():
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    spec = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for arch, (L_, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L_, d, h, kv, ff, v), arch
    assert get_config("granite-moe-1b-a400m").num_experts == 32
    assert get_config("granite-moe-1b-a400m").moe_top_k == 8
    assert get_config("qwen2-moe-a2.7b").num_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe_top_k == 4
    assert get_config("falcon-mamba-7b").ssm_state == 16
    assert get_config("zamba2-2.7b").ssm_state == 64
    assert get_config("gemma-2b").head_dim == 256
