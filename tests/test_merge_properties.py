"""Hypothesis property suite for MultiSketch merge algebra.

Properties (all BIT-identical, not just statistically equal — exact merge
is the paper's §3.3 composability claim):

  * commutativity / associativity of ``multisketch_merge``;
  * absorb-then-merge == merge-then-absorb (streaming and fan-in folds
    interleave freely);
  * incremental delta fold (``multisketch_absorb_into``) == full stacked
    re-merge (the PR 5 engine contract);
  * threshold closure: every finite tau^(f)'s threshold key is retained in
    the slab, and re-selection over the slab alone is idempotent.

Random key/weight/scheme/capacity draws ride hypothesis when installed
(CI installs it); the checkers are plain functions, and a deterministic
parametrized sweep below exercises the same properties at fixed draws so
the invariants stay tier-1-covered where hypothesis is absent.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.core.multi_sketch import (MultiSketch, multisketch_absorb_into,
                                     multisketch_merge_stacked)

_POOL = [(C.SUM, 5), (C.COUNT, 3), (C.thresh(2.0), 4), (C.cap(1.5), 3),
         (C.moment(1.5), 3)]


def _make_spec(scheme, nf, capacity_slack, seed):
    base = C.MultiSketchSpec(objectives=tuple(_POOL[:nf]), scheme=scheme,
                             seed=seed)
    if capacity_slack:
        base = C.MultiSketchSpec(objectives=tuple(_POOL[:nf]), scheme=scheme,
                                 seed=seed,
                                 capacity=base.default_capacity()
                                 + capacity_slack)
    return base


def _assert_bitsame(a: MultiSketch, b: MultiSketch, msg=""):
    for name, x, y in zip(MultiSketch._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg}{name}")


def _parts(keys, ws, cuts):
    """Split (keys, ws) at relative cut points into >= 1 chunks."""
    n = len(keys)
    idx = sorted({max(1, min(n - 1, int(c * n))) for c in cuts}) if n > 1 \
        else []
    return [(keys[a:b], ws[a:b])
            for a, b in zip([0] + idx, idx + [n]) if b > a]


# ------------------------------------------------------------- checkers
def check_merge_commutative(spec, keys, ws):
    parts = _parts(keys, ws, [0.5])
    if len(parts) < 2:
        return
    a = C.multisketch_build(spec, *parts[0])
    b = C.multisketch_build(spec, *parts[1])
    _assert_bitsame(C.multisketch_merge(spec, a, b),
                    C.multisketch_merge(spec, b, a), "commutative: ")


def check_merge_associative(spec, keys, ws):
    parts = _parts(keys, ws, [0.33, 0.66])
    sks = [C.multisketch_build(spec, k, w) for k, w in parts]
    if len(sks) < 3:
        return
    a, b, c = sks[:3]
    left = C.multisketch_merge(spec, C.multisketch_merge(spec, a, b), c)
    right = C.multisketch_merge(spec, a, C.multisketch_merge(spec, b, c))
    _assert_bitsame(left, right, "associative: ")
    # and both equal the one-shot union build (keys are distinct)
    _assert_bitsame(left, C.multisketch_build(spec, keys, ws), "vs whole: ")


def check_absorb_merge_interchange(spec, keys, ws):
    """absorb-then-merge == merge-then-absorb == one-shot."""
    parts = _parts(keys, ws, [0.4, 0.7])
    if len(parts) < 3:
        return
    (k1, w1), (k2, w2), (k3, w3) = parts[:3]
    a = C.multisketch_build(spec, k1, w1)
    b = C.multisketch_build(spec, k2, w2)
    absorb_then_merge = C.multisketch_merge(
        spec, C.multisketch_absorb(jax.tree.map(jnp.copy, a), k3, w3,
                                   spec=spec, use_kernels=False), b)
    merge_then_absorb = C.multisketch_absorb(
        C.multisketch_merge(spec, a, b), k3, w3, spec=spec,
        use_kernels=False)
    _assert_bitsame(absorb_then_merge, merge_then_absorb, "interchange: ")


def check_incremental_equals_full(spec, keys, ws):
    """Delta fold into a cached merge == full stacked re-merge."""
    parts = _parts(keys, ws, [0.3, 0.6, 0.8])
    if len(parts) < 3:
        return
    sks = [C.multisketch_build(spec, k, w) for k, w in parts]
    cached = sks[0]
    for s in sks[1:-1]:
        cached = C.multisketch_merge(spec, cached, s)
    inc = multisketch_absorb_into(jax.tree.map(jnp.copy, cached), sks[-1],
                                  spec=spec, use_kernels=False)
    stacked = MultiSketch(*jax.tree.map(lambda *xs: jnp.stack(xs), *sks))
    full = multisketch_merge_stacked(spec, stacked)
    _assert_bitsame(inc, full, "incremental vs full: ")


def check_threshold_closure(spec, keys, ws):
    """Every objective's finite tau has its threshold key retained, and
    re-selection over the slab alone reproduces the slab (idempotence)."""
    sk = C.multisketch_build(spec, keys, ws)
    seeds = np.asarray(sk.seeds)
    valid = np.asarray(sk.valid)
    for fi, tau in enumerate(np.asarray(sk.taus)):
        if np.isfinite(tau):
            assert np.any(valid & (seeds[fi] == tau)), \
                f"threshold key of objective {fi} not retained"
    _assert_bitsame(
        C.multisketch_merge(spec, sk, C.multisketch_empty(spec)), sk,
        "idempotence: ")


_CHECKS = [check_merge_commutative, check_merge_associative,
           check_absorb_merge_interchange, check_incremental_equals_full,
           check_threshold_closure]


def _draw_to_inputs(key_seed, ws):
    rng = np.random.default_rng(key_seed)
    keys = rng.choice(200_000, size=len(ws), replace=False).astype(np.int32)
    return keys, np.asarray(ws, np.float32)


# ------------------------------------------------- deterministic sweep
@pytest.mark.parametrize("check", _CHECKS,
                         ids=lambda c: c.__name__.replace("check_", ""))
@pytest.mark.parametrize("scheme,nf,slack,seed", [
    ("ppswor", 3, 0, 0), ("priority", 3, 0, 7),
    ("ppswor", 5, 9, 3), ("priority", 1, 4, 1)])
def test_merge_properties_fixed_draws(check, scheme, nf, slack, seed):
    spec = _make_spec(scheme, nf, slack, seed)
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(12, 90))
    ws = rng.lognormal(0, 1.4, n).astype(np.float32)
    keys, ws = _draw_to_inputs(seed, ws)
    check(spec, keys, ws)


# ------------------------------------------------- hypothesis wrappers
# soft gate (importorskip would skip the deterministic sweep above too):
# when hypothesis is absent the random-draw wrappers are skipped but the
# fixed-draw sweep still runs under tier-1.
try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("ci", deadline=None, max_examples=20)
    settings.load_profile("ci")
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    weights_strategy = st.lists(
        st.floats(min_value=0.0009765625, max_value=16384.0,
                  allow_nan=False, allow_infinity=False, width=32),
        min_size=6, max_size=80)
    draw_strategy = st.tuples(st.integers(0, 10_000), weights_strategy,
                              st.sampled_from(["ppswor", "priority"]),
                              st.integers(1, 5), st.integers(0, 12),
                              st.integers(0, 1000))

    def _run(check, draw):
        key_seed, ws, scheme, nf, slack, hash_seed = draw
        spec = _make_spec(scheme, nf, slack, hash_seed)
        keys, ws = _draw_to_inputs(key_seed, ws)
        check(spec, keys, ws)

    @given(draw_strategy)
    def test_merge_commutative(draw):
        _run(check_merge_commutative, draw)

    @given(draw_strategy)
    def test_merge_associative(draw):
        _run(check_merge_associative, draw)

    @given(draw_strategy)
    def test_absorb_merge_interchange(draw):
        _run(check_absorb_merge_interchange, draw)

    @given(draw_strategy)
    def test_incremental_equals_full(draw):
        _run(check_incremental_equals_full, draw)

    @given(draw_strategy)
    def test_threshold_closure(draw):
        _run(check_threshold_closure, draw)
else:  # pragma: no cover - environment-dependent
    def test_hypothesis_missing_marker():
        pytest.skip("hypothesis not installed; random-draw suite skipped "
                    "(fixed-draw sweep above still ran)")
