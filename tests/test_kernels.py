"""Per-kernel shape/dtype sweeps: pallas (interpret=True) vs ref.py oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.kernels as K
from repro.kernels import ref as R
from repro.core.hashing import uniform01, rank_of


OBJS = ((0, 0.0), (3, 2.0), (1, 0.0), (2, 5.0), (4, 1.5))


@pytest.mark.parametrize("n", [1024, 2048, 8192])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
def test_fused_seeds_matches_oracle(rng, n, dtype, scheme):
    keys = rng.integers(0, 2**31 - 1, n).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(dtype)
    act = rng.random(n) > 0.1
    out = K.fused_seeds(jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act),
                        OBJS, scheme=scheme, seed=5)
    ref = R.fused_seeds_ref(keys, w.astype(np.float32), act, OBJS,
                            scheme=scheme, seed=5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [512, 1024, 4096])
@pytest.mark.parametrize("sigma", [0.5, 2.5])
def test_rank_counts_matches_oracle(rng, n, sigma):
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, sigma, n).astype(np.float32)
    act = rng.random(n) > 0.07
    u = uniform01(keys, 0)
    r = rank_of(u, "ppswor")
    rw = jnp.where(act, r / jnp.maximum(jnp.asarray(w), 1e-30), jnp.inf)
    h_k, l_k = K.rank_counts(jnp.where(act, w, 0), u, rw, act)
    h_r, l_r = R.rank_counts_ref(jnp.where(act, w, 0), u, rw, act)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


@pytest.mark.parametrize("n,k", [(2048, 1), (2048, 16), (4096, 64),
                                 (8192, 33)])
def test_block_bottomk_and_global_select(rng, n, k):
    seeds = rng.exponential(1.0, n).astype(np.float32)
    seeds[rng.random(n) > 0.9] = np.inf  # inactive seeds
    b = min(2048, n)
    v_k, i_k = K.block_bottomk(jnp.asarray(seeds), k)
    v_r, i_r = R.block_bottomk_ref(seeds, k, b)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    gv, gi, gtau = K.bottomk_select(jnp.asarray(seeds), k)
    rv, ri, rtau = R.bottomk_select_ref(seeds, k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(rv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    assert float(gtau) == float(rtau)


def test_kernel_composition_matches_core_multi_objective(rng):
    import repro.core as C
    n, k = 4096, 16
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    act = rng.random(n) > 0.05
    objs = ((0, 0.0), (3, 2.0), (1, 0.0))
    m_k, p_k = K.ops.multi_objective_bottomk_kernel(
        jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act), objs, k)
    core = C.multi_bottomk_sample(keys, w, act,
                                  [(C.SUM, k), (C.cap(2.0), k), (C.COUNT, k)],
                                  seed=0)
    assert bool(jnp.all(m_k == core.member))
    assert bool(jnp.allclose(p_k, core.prob, atol=1e-6))


def test_capping_kernel_matches_core_ref(rng):
    import repro.core as C
    n, k = 2048, 16
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    act = rng.random(n) > 0.05
    u = uniform01(keys, 0)
    m2, hl = K.ops.universal_capping_kernel(jnp.asarray(keys), jnp.asarray(w),
                                            jnp.asarray(act), k)
    cr = C.universal_capping_ref(w, np.asarray(u), act, k)
    assert bool(jnp.all(m2 == cr.member))
    # hl diagnostic is only defined for ACTIVE keys (the kernel zeroes
    # inactive rows; the ref counts their raw-weight pairs)
    assert bool(jnp.all(jnp.where(jnp.asarray(act), hl == cr.hl, True)))
