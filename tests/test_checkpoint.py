"""Sketch checkpointing + cross-job merge: spec metadata round-trip,
engine save/restore bit-identity, restore -> merge -> query exactness, and
corrupt-checkpoint fallback."""
import os

import numpy as np
import pytest

import repro.core as C
from repro.ckpt.manager import CheckpointManager
from repro.core.multi_sketch import spec_from_meta, spec_to_meta
from repro.launch.query import SegmentQueryEngine

from tests.faults import CKPT_CORRUPTIONS, corrupt_checkpoint


def _objectives():
    return ((C.SUM, 16), (C.COUNT, 8), (C.thresh(2.0), 12))


def _data(n=2400, seed=3):
    rng = np.random.default_rng(seed)
    keys = rng.permutation(np.arange(n)).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    return keys, w


def test_spec_meta_roundtrip_including_combo():
    for spec in (
            C.MultiSketchSpec(objectives=_objectives(), seed=9),
            C.MultiSketchSpec(objectives=((C.moment(1.5), 4),),
                              scheme="priority", capacity=64),
            C.MultiSketchSpec(objectives=(
                (C.combo((2.0, C.SUM), (0.5, C.cap(3.0))), 8),), seed=1)):
        back = spec_from_meta(spec_to_meta(spec))
        assert back == spec
        import json
        json.dumps(spec_to_meta(spec))  # must be JSON-able


def test_engine_checkpoint_roundtrip_bit_identical(tmp_path):
    keys, w = _data()
    spec = C.MultiSketchSpec(objectives=_objectives(), seed=5)
    eng = SegmentQueryEngine(spec, shards=2, b_quantum=8)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    eng.save_checkpoint(str(tmp_path), step=7)

    eng2 = SegmentQueryEngine.from_checkpoint(str(tmp_path))
    assert eng2.spec == spec
    assert eng2.num_shards == 2 and eng2.b_quantum == 8
    for a, b in zip(eng.merged, eng2.merged):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    preds = [C.EVERYTHING, C.key_range(0, 1199), C.hash_fraction(0.5)]
    np.testing.assert_array_equal(eng.query_many(predicates=preds),
                                  eng2.query_many(predicates=preds))
    # the restored engine keeps absorbing (donated fold on fresh buffers);
    # heavy keys MUST enter the SUM sample, so the estimate reflects them
    before = eng2.query(C.SUM)
    eng2.absorb(np.arange(50_000, 50_100),
                np.full(100, 1000.0, np.float32))
    assert eng2.query(C.SUM) > before + 50_000


def test_restore_merge_query_roundtrip(tmp_path):
    """Cross-job fan-in: restore job B's slabs into job A's engine; the
    merged answer equals a one-shot build over the union data set."""
    keys_a, w_a = _data(seed=1)
    keys_b = (100_000 + np.arange(1500)).astype(np.int32)
    w_b = np.random.default_rng(2).lognormal(0, 1.5, 1500).astype(np.float32)
    spec = C.MultiSketchSpec(objectives=_objectives(), seed=11)

    da, db = str(tmp_path / "job_a"), str(tmp_path / "job_b")
    ea = SegmentQueryEngine(spec, shards=2)
    ea.absorb(keys_a[::2], w_a[::2], shard=0)
    ea.absorb(keys_a[1::2], w_a[1::2], shard=1)
    ea.save_checkpoint(da)
    eb = SegmentQueryEngine(spec)
    eb.absorb(keys_b, w_b)
    eb.save_checkpoint(db)

    eng = SegmentQueryEngine.from_checkpoint(da)
    donor = SegmentQueryEngine.from_checkpoint(db)
    for s in donor._shards:
        eng.add_shard(s)
    assert eng.num_shards == 3

    union = C.multisketch_merge(
        spec, C.multisketch_build(spec, keys_a, w_a),
        C.multisketch_build(spec, keys_b, w_b))
    for f, _ in spec.objectives:
        got = eng.query(f)
        want = float(C.multisketch_estimate(union, f))
        assert got == pytest.approx(want, rel=1e-5), f
    # segment restricted to job B's key range: only B's mass
    got_b = eng.query(C.SUM, C.key_range(100_000, 200_000))
    want_b = float(C.multisketch_estimate(
        union, C.SUM, segment_fn=lambda k: (k >= 100_000)))
    assert got_b == pytest.approx(want_b, rel=1e-5)


def test_corrupt_checkpoint_falls_back_to_previous(tmp_path):
    """Fallback must pair meta AND arrays from the SAME step: the corrupt
    newest save has MORE shards than the intact older one, so mixing the
    newest metadata with the older arrays could never restore."""
    keys, w = _data(seed=4)
    spec = C.MultiSketchSpec(objectives=_objectives(), seed=2)
    eng = SegmentQueryEngine(spec)
    eng.absorb(keys[:1000], w[:1000])
    eng.save_checkpoint(str(tmp_path), step=1)
    want = eng.query(C.SUM)
    eng.absorb(keys[1000:], w[1000:])
    extra = C.multisketch_build(spec, np.arange(10_000, 10_200),
                                np.ones(200, np.float32))
    eng.add_shard(extra)
    eng.save_checkpoint(str(tmp_path), step=2)
    # corrupt the newest step's arrays -> restore must fall back to step 1
    step2 = tmp_path / "step_0000000002"
    victim = next(p for p in sorted(os.listdir(step2))
                  if p.endswith(".npy"))
    with open(step2 / victim, "r+b") as f:
        f.seek(60)
        f.write(b"\xff" * 64)
    eng2 = SegmentQueryEngine.from_checkpoint(str(tmp_path))
    assert eng2.num_shards == 1
    assert eng2.query(C.SUM) == pytest.approx(want, rel=1e-6)


@pytest.mark.parametrize("mode", CKPT_CORRUPTIONS)
def test_corruption_matrix_restores_without_raising(tmp_path, mode):
    """Every damage mode in the matrix — flipped byte, truncated array,
    deleted manifest, leftover .tmp from a crashed save, missing array
    file — must fall back via restore_latest without raising."""
    keys, w = _data(seed=8)
    spec = C.MultiSketchSpec(objectives=_objectives(), seed=6)
    eng = SegmentQueryEngine(spec)
    eng.absorb(keys[:800], w[:800])
    eng.save_checkpoint(str(tmp_path), step=1)
    want_step1 = eng.query(C.SUM)
    eng.absorb(keys[800:], w[800:])
    eng.save_checkpoint(str(tmp_path), step=2)
    want_step2 = eng.query(C.SUM)

    corrupt_checkpoint(str(tmp_path), mode)
    eng2 = SegmentQueryEngine.from_checkpoint(str(tmp_path))
    if mode == "tmp_dir":
        # a leftover partial save dir is IGNORED; newest step still loads
        assert eng2.query(C.SUM) == pytest.approx(want_step2, rel=1e-6)
    else:
        # damaged newest step -> silent fallback to the intact step 1
        assert eng2.query(C.SUM) == pytest.approx(want_step1, rel=1e-6)


def test_corruption_of_every_step_raises_cleanly(tmp_path):
    """No intact step left: the engine loader surfaces a clean
    FileNotFoundError, not a decode crash."""
    spec = C.MultiSketchSpec(objectives=_objectives(), seed=6)
    eng = SegmentQueryEngine(spec)
    eng.absorb(np.arange(100), np.ones(100, np.float32))
    eng.save_checkpoint(str(tmp_path), step=1)
    corrupt_checkpoint(str(tmp_path), "truncate_array")
    with pytest.raises(FileNotFoundError):
        SegmentQueryEngine.from_checkpoint(str(tmp_path))


def test_save_checkpoint_default_step_auto_bumps(tmp_path):
    """Re-saving an updated engine must not be silently dropped by the
    manager's step-exists skip — the default step mints a fresh number."""
    spec = C.MultiSketchSpec(objectives=_objectives(), seed=3)
    eng = SegmentQueryEngine(spec)
    eng.absorb(np.arange(300), np.ones(300, np.float32))
    eng.save_checkpoint(str(tmp_path))
    eng.absorb(np.arange(1000, 1300), np.full(300, 5.0, np.float32))
    eng.save_checkpoint(str(tmp_path))
    eng2 = SegmentQueryEngine.from_checkpoint(str(tmp_path))
    assert eng2.query(C.SUM) == pytest.approx(eng.query(C.SUM), rel=1e-6)


def test_read_meta_missing_dir_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "empty"))
    with pytest.raises(FileNotFoundError):
        mgr.read_meta()
    with pytest.raises(FileNotFoundError):
        SegmentQueryEngine.from_checkpoint(str(tmp_path / "empty2"))
