"""Core sampling library: correctness vs paper definitions + oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.core as C


def make_data(rng, n, sigma=1.5, dup_frac=0.0):
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, sigma, n).astype(np.float32)
    if dup_frac > 0:  # force repeated weights (tie handling paths)
        m = int(n * dup_frac)
        w[:m] = np.round(w[:m], 1)
    active = rng.random(n) > 0.05
    return keys, w, active


# ---------------------------------------------------------------- paper toy
def test_paper_example_1_1_exact_statistics():
    w = np.array([5, 100, 23, 7, 1, 5, 220, 19, 3, 2], np.float32)
    act = np.ones(10, bool)
    H = np.isin(np.arange(10), [1, 3, 7, 9])
    assert float(C.exact(C.SUM, w, act, H)) == 128
    assert float(C.exact(C.COUNT, w, act, H)) == 4
    assert float(C.exact(C.thresh(10), w, act, H)) == 2
    assert float(C.exact(C.cap(5), w, act, H)) == 17
    assert float(C.exact(C.moment(2), w, act, H)) == 10414


def test_paper_example_2_1_pps_probabilities():
    w = np.array([5, 100, 23, 7, 1, 5, 220, 19, 3, 2], np.float32)
    act = np.ones(10, bool)
    p, s = C.pps_probabilities(w, act, C.SUM, 3)
    assert float(s) == 385
    np.testing.assert_allclose(np.round(np.asarray(p), 2),
                               [.04, .78, .18, .05, .01, .04, 1., .15, .02, .02])
    p, s = C.pps_probabilities(w, act, C.thresh(10), 3)
    assert float(s) == 4
    np.testing.assert_allclose(
        np.asarray(p), [0, .75, .75, 0, 0, 0, .75, .75, 0, 0], atol=1e-6)


def test_paper_example_3_1_multi_objective_size():
    w = np.array([5, 100, 23, 7, 1, 5, 220, 19, 3, 2], np.float32)
    act = np.ones(10, bool)
    objs = [(C.SUM, 3), (C.thresh(10), 3), (C.cap(5), 3)]
    probs = [C.pps_probabilities(w, act, f, k)[0] for f, k in objs]
    pF = jnp.stack(probs).max(0)
    naive = float(sum(p.sum() for p in probs))
    assert abs(naive - 8.29) < 0.01          # paper's naive total
    assert float(pF.sum()) < naive            # multi-objective strictly smaller
    assert abs(float(pF.sum()) - 4.816) < 0.01  # exact Eq.4 value


# ------------------------------------------------------------- equivalences
@pytest.mark.parametrize("dup", [0.0, 0.5])
@pytest.mark.parametrize("k", [1, 4, 16])
def test_universal_monotone_prod_matches_ref(rng, k, dup):
    keys, w, act = make_data(rng, 300, dup_frac=dup)
    u = np.asarray(C.uniform01(keys, 7))
    ref = C.universal_monotone_ref(w, u, act, k)
    prod = C.universal_monotone_sample(keys, w, act, k, seed=7)
    assert bool(jnp.all(ref.member == prod.member))
    assert bool(jnp.allclose(ref.prob, prod.prob, atol=1e-6))
    assert bool(jnp.all(ref.aux == prod.aux))


@pytest.mark.parametrize("k", [2, 8])
def test_universal_capping_prod_matches_ref(rng, k):
    keys, w, act = make_data(rng, 250)
    u = np.asarray(C.uniform01(keys, 3))
    ref = C.universal_capping_ref(w, u, act, k)
    prod = C.universal_capping_sample(keys, w, act, k, m_cap=250, seed=3)
    assert bool(jnp.all(ref.member == prod.member))
    assert bool(jnp.all(ref.hl == prod.hl))
    assert bool(jnp.allclose(jnp.where(ref.member, ref.prob, 0),
                             jnp.where(prod.member, prod.prob, 0), atol=1e-5))


def test_capping_subset_of_monotone(rng):
    """S^(C,k) ⊆ S^(M,k) (paper §6.2) under shared randomization."""
    keys, w, act = make_data(rng, 400)
    u = np.asarray(C.uniform01(keys, 11))
    mono = C.universal_monotone_ref(w, u, act, 8)
    capg = C.universal_capping_ref(w, u, act, 8)
    assert bool(jnp.all(capg.member <= mono.member))
    assert int(capg.member.sum()) < int(mono.member.sum())


def test_multi_objective_union_and_dominance(rng):
    keys, w, act = make_data(rng, 400)
    objs = [(C.SUM, 8), (C.thresh(5.0), 8), (C.cap(2.0), 8)]
    mb = C.multi_bottomk_sample(keys, w, act, objs, seed=0)
    for f, kf in objs:
        ded = C.bottomk_sample(keys, w, act, f, kf, seed=0)
        assert bool(jnp.all(ded.member <= mb.member))
        assert bool(jnp.all(jnp.where(ded.member,
                                      mb.prob >= ded.prob - 1e-6, True)))


def test_sample_size_bounds(rng):
    n, k = 2000, 8
    keys, w, act = make_data(rng, n)
    sizes_m, sizes_c = [], []
    for s in range(30):
        u = np.asarray(C.uniform01(keys, s))
        sizes_m.append(int(C.universal_monotone_ref(w, u, act, k).member.sum()))
        sizes_c.append(int(C.universal_capping_ref(w, u, act, k).member.sum()))
    assert np.mean(sizes_m) <= C.expected_size_bound(n, k)           # Thm 5.1
    assert np.mean(sizes_c) <= C.capping_size_bound(k, w[act].max(),
                                                    w[act].min())    # Thm 6.1
    assert np.mean(sizes_c) < np.mean(sizes_m)


# ------------------------------------------------------------ estimation
@pytest.mark.parametrize("fname,f", [
    ("sum", C.SUM), ("count", C.COUNT), ("thresh2", C.thresh(2.0)),
    ("cap1", C.cap(1.0)), ("mom1.5", C.moment(1.5))])
def test_universal_monotone_unbiased(rng, fname, f):
    keys, w, act = make_data(rng, 400)
    H = (np.arange(400) % 3 == 0)
    ex = float(C.exact(f, w, act, H))
    ests = []
    for s in range(200):
        sm = C.universal_monotone_sample(keys, w, act, 16, seed=s)
        ests.append(float(C.estimate(f, w, sm.prob, sm.member, H)))
    assert abs(np.mean(ests) / ex - 1) < 0.11, (np.mean(ests), ex)


def test_cv_within_gold_standard_bound(rng):
    """CV <= 1/sqrt(q (k-1)) for f in M from S^(M,k) (paper §5.1)."""
    keys, w, act = make_data(rng, 500)
    k = 24
    for f in [C.SUM, C.thresh(3.0), C.cap(2.0)]:
        ex = float(C.exact(f, w, act))
        q = 1.0
        ests = [float(C.estimate(f, w, s.prob, s.member))
                for s in (C.universal_monotone_sample(keys, w, act, k, seed=i)
                          for i in range(150))]
        cv = np.std(ests) / ex
        assert cv <= C.cv_bound(q, k) * 1.25, (f.name, cv, C.cv_bound(q, k))


def test_closure_theorem_4_1(rng):
    """pps multi-objective sample for F covers any nonneg combo of F."""
    keys, w, act = make_data(rng, 300)
    F = [(C.SUM, 5), (C.cap(2.0), 5)]
    combo = C.combo((0.7, C.SUM), (2.0, C.cap(2.0)))
    pF = jnp.stack([C.pps_probabilities(w, act, f, k)[0] for f, k in F]).max(0)
    pc, _ = C.pps_probabilities(w, act, combo, 5)
    # Thm 4.1: p^(combo) <= p^(F) pointwise => S^(F u combo) = S^(F)
    assert bool(jnp.all(pc <= pF + 1e-6))


def test_bottomk_conditional_probabilities_unbiased(rng):
    keys, w, act = make_data(rng, 300)
    for scheme in ("ppswor", "priority"):
        ex = float(C.exact(C.SUM, w, act))
        ests = [float(C.estimate(C.SUM, w, s.prob, s.member))
                for s in (C.bottomk_sample(keys, w, act, C.SUM, 16, scheme,
                                           seed=i) for i in range(150))]
        assert abs(np.mean(ests) / ex - 1) < 0.09


# ------------------------------------------------------------ mergeability
def test_merge_matches_whole_data_sketch(rng):
    n, k = 600, 8
    keys, w, act = make_data(rng, n)
    cap_sz = C.sketch_capacity(n, k)
    parts = np.array_split(np.arange(n), 4)
    sks = [C.build_sketch(keys[p], w[p], act[p], k, cap_sz, seed=3)
           for p in parts]
    merged = sks[0]
    for s in sks[1:]:
        merged = C.merge_sketches(merged, s)
    whole = C.build_sketch(keys, w, act, k, cap_sz, seed=3)

    def as_set(sk):
        return {(int(a), float(b), round(float(p), 6))
                for a, b, p, m, v in zip(sk.keys, sk.weights, sk.probs,
                                         sk.member, sk.valid) if v and m}
    assert as_set(merged) == as_set(whole)


def test_merge_dedups_keys_keeping_max_weight(rng):
    k = 4
    keys = np.array([1, 2, 3, 4], np.int32)
    w1 = np.array([1., 5., 2., 1.], np.float32)
    w2 = np.array([3., 1., 2., 8.], np.float32)
    act = np.ones(4, bool)
    a = C.build_sketch(keys, w1, act, k, 16, seed=0)
    b = C.build_sketch(keys, w2, act, k, 16, seed=0)
    m = C.merge_sketches(a, b)
    got = {int(kk): float(ww) for kk, ww, v in
           zip(m.keys, m.weights, m.valid) if v}
    assert got[1] == 3. and got[2] == 5. and got[4] == 8.
