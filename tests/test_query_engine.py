"""Segment-query engine: batched query_many vs the single-estimate oracle
across schemes x |F| x B, single-launch flatness in both B and |F|, lazy
merge-on-demand == eager sharded build, absorb-epoch cache invalidation;
plus the PR's satellites (blocked buffer scan bit-identity, jit-cached /
donated merge_sketches, collector query routing)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as C
from repro.core.predicates import PRED_COLS, never_row, pad_table
from repro.kernels import ref as R
from repro.kernels.segquery import segment_query_slab
from repro.launch.query import SegmentQueryEngine
from tests.test_batched_multiobj import _count_pallas_calls


def _objectives(nf):
    pool = [(C.SUM, 16), (C.COUNT, 8), (C.thresh(2.0), 12), (C.cap(1.5), 8),
            (C.moment(1.5), 8), (C.thresh(0.5), 8), (C.cap(4.0), 8),
            (C.moment(0.5), 8)]
    return tuple(pool[:nf])


def _predicates(b, lo=0, hi=10_000):
    """b deterministic predicates cycling through every wire family."""
    span = max((hi - lo) // max(b, 1), 1)
    pool = []
    for i in range(b):
        fam = i % 4
        if fam == 0:
            pool.append(C.key_range(lo + i * span, lo + (i + 1) * span - 1))
        elif fam == 1:
            pool.append(C.key_mask(3, i % 4))
        elif fam == 2:
            pool.append(C.hash_fraction(0.1 + 0.8 * (i / max(b, 1)), salt=i))
        else:
            pool.append(C.EVERYTHING)
    return pool


def _data(n=2500, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.permutation(np.arange(5, 5 + n)).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    return keys, w


# ------------------------------------------------ batched query vs oracles
@pytest.mark.parametrize("scheme", ["ppswor", "priority"])
@pytest.mark.parametrize("nf", [1, 3, 8])
@pytest.mark.parametrize("b", [1, 16, 128])
def test_query_many_matches_single_estimates(scheme, nf, b):
    keys, w = _data()
    objs = _objectives(nf)
    spec = C.MultiSketchSpec(objectives=objs, scheme=scheme, seed=11)
    sk = C.multisketch_build(spec, keys, w)
    preds = _predicates(b)
    fs = tuple(f for f, _ in objs)
    got = C.multisketch_estimate_batch(sk, fs, preds)
    assert got.shape == (nf, b)
    for i, f in enumerate(fs):
        for j, p in enumerate(preds):
            want = float(C.multisketch_estimate(sk, f, segment_fn=p))
            assert abs(float(got[i, j]) - want) <= 1e-3 * max(1.0, abs(want))


def test_kernel_and_xla_paths_identical():
    keys, w = _data(seed=3)
    objs = _objectives(3)
    spec = C.MultiSketchSpec(objectives=objs, seed=7)
    sk = C.multisketch_build(spec, keys, w)
    preds = _predicates(16)
    fs = tuple(f for f, _ in objs)
    a = C.multisketch_estimate_batch(sk, fs, preds, use_kernels=True)
    x = C.multisketch_estimate_batch(sk, fs, preds, use_kernels=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(x), rtol=1e-5)
    r = R.segment_query_ref(sk.keys, sk.weights, sk.probs, sk.member,
                            C.encode_predicates(preds),
                            ((0, 0.0), (1, 0.0), (2, 2.0)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-5)


def test_estimate_many_matches_estimate():
    rng = np.random.default_rng(2)
    n = 800
    w = rng.lognormal(0, 1, n).astype(np.float32)
    probs = np.clip(rng.random(n), 0.05, 1).astype(np.float32)
    member = rng.random(n) > 0.4
    segs = rng.random((5, n)) > 0.5
    fs = (C.SUM, C.COUNT, C.cap(1.5))
    got = C.estimate_many(fs, w, probs, member, segs)
    for i, f in enumerate(fs):
        for j in range(5):
            want = float(C.estimate(f, w, probs, member, segs[j]))
            np.testing.assert_allclose(float(got[i, j]), want, rtol=1e-5)
    # disjoint segment rows agree with the partition estimator
    ids = rng.integers(0, 4, n)
    part = np.stack([ids == j for j in range(4)])
    got_p = C.estimate_many((C.SUM,), w, probs, member, part)[0]
    want_p = C.estimate_segments(C.SUM, w, probs, member, ids, 4)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                               rtol=1e-5)


def test_predicate_wire_semantics():
    keys = np.arange(-2, 1000, dtype=np.int32)
    m = C.predicate_matrix(keys, C.encode_predicates(
        [C.key_range(10, 20), C.key_mask(1, 1), C.EVERYTHING,
         C.SegmentPredicate(lo=1, hi=0)]))
    m = np.asarray(m)
    np.testing.assert_array_equal(m[0], (keys >= 10) & (keys <= 20))
    np.testing.assert_array_equal(m[1], (keys % 2 == 1) & (keys >= 0))
    np.testing.assert_array_equal(m[2], keys >= 0)
    assert not m[3].any()
    # hashed fraction selects ~q of keys, coordinated (same salt -> same set)
    frac = C.hash_fraction(0.25, salt=9)
    sel = np.asarray(frac(np.arange(20_000)))
    assert abs(sel.mean() - 0.25) < 0.02
    np.testing.assert_array_equal(sel, np.asarray(frac(np.arange(20_000))))
    assert pad_table(C.encode_predicates([C.EVERYTHING]), 4).shape == \
        (4, PRED_COLS)
    assert (never_row()[0] > never_row()[1])


# ------------------------------------------------ single-launch flatness
@pytest.mark.parametrize("nf", [1, 3, 8])
@pytest.mark.parametrize("b", [1, 16, 128])
def test_query_launch_count_flat_in_B_and_F(nf, b):
    """ONE pallas launch per query_many, for every (B, |F|) combination."""
    objs = _objectives(nf)
    spec = C.MultiSketchSpec(objectives=objs, seed=1)
    sk = C.multisketch_build(spec, np.arange(500), np.ones(500, np.float32))
    enc = spec.kernel_objectives()
    table = jnp.asarray(C.encode_predicates(_predicates(b)))
    jx = jax.make_jaxpr(
        lambda k, w, p, m, t: segment_query_slab(k, w, p, m, t, enc))(
            sk.keys, sk.weights, sk.probs, sk.member, table)
    assert _count_pallas_calls(jx.jaxpr) == 1


# ------------------------------------------------ engine: lazy merge, cache
def test_engine_lazy_merge_matches_one_shot_and_invalidates():
    keys, w = _data(n=3000, seed=5)
    objs = _objectives(3)
    spec = C.MultiSketchSpec(objectives=objs, seed=2)
    eng = SegmentQueryEngine(spec, shards=4)
    for i in range(4):
        eng.absorb(keys[i::4], w[i::4], shard=i)
    one = C.multisketch_build(spec, keys, w)
    m = eng.merged
    np.testing.assert_array_equal(np.asarray(m.keys), np.asarray(one.keys))
    np.testing.assert_array_equal(np.asarray(m.probs), np.asarray(one.probs))
    np.testing.assert_array_equal(np.asarray(m.taus), np.asarray(one.taus))
    # memoized: same epoch -> same object, no re-merge
    assert eng.merged is m
    # absorb invalidates; the next query reflects the union
    e0 = eng.epoch
    extra_k = np.arange(90_000, 90_064)
    extra_w = np.full(64, 2.0, np.float32)
    eng.absorb(extra_k, extra_w, shard=2)
    assert eng.epoch > e0 and eng.merged is not m
    want = C.multisketch_merge(spec, one,
                               C.multisketch_build(spec, extra_k, extra_w))
    got = eng.query(C.SUM)
    ref = float(C.multisketch_estimate(want, C.SUM))
    assert abs(got - ref) <= 1e-3 * max(1.0, abs(ref))


def test_engine_merged_handle_survives_absorb():
    """Single-shard fast path: a handed-out merged slab must stay readable
    after the next (donated) absorb invalidates the engine's state."""
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=4)
    eng = SegmentQueryEngine(spec)
    eng.absorb(np.arange(300), np.ones(300, np.float32))
    held = eng.merged
    before = int(jnp.sum(held.member))
    eng.absorb(np.arange(1000, 1300), np.ones(300, np.float32))
    assert int(jnp.sum(held.member)) == before   # not donated away
    assert eng.merged is not held


def test_engine_set_shard_copies_installed_slab():
    """A slab installed via set_shard must remain the CALLER's — the next
    absorb donates the resident buffers, never the installed handle."""
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=6)
    installed = C.multisketch_build(spec, np.arange(200),
                                    np.ones(200, np.float32))
    before = int(jnp.sum(installed.member))
    eng = SegmentQueryEngine(spec)
    eng.set_shard(0, installed)
    eng.absorb(np.arange(5000, 5200), np.ones(200, np.float32))
    assert int(jnp.sum(installed.member)) == before
    assert eng.query(C.COUNT) > 0


def test_engine_query_many_shapes_and_bucketing():
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=3)
    eng = SegmentQueryEngine(spec)
    eng.absorb(np.arange(300), np.ones(300, np.float32))
    out = eng.query_many(predicates=_predicates(5))   # padded to b_quantum
    assert out.shape == (2, 5)
    single = eng.query(C.SUM, C.key_range(0, 149))
    batch = eng.query_many((C.SUM,), (C.key_range(0, 149),))
    assert abs(single - float(batch[0, 0])) < 1e-5 * max(1.0, abs(single))


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax
    import repro.core as C
    from repro.launch.summary import sharded_multisketch
    from repro.launch.query import SegmentQueryEngine

    rng = np.random.default_rng(4)
    n = 4096
    keys = rng.permutation(np.arange(n)).astype(np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    mesh = jax.make_mesh((4,), ("data",))
    spec = C.MultiSketchSpec(objectives=((C.SUM, 16), (C.COUNT, 8),
                                         (C.thresh(2.0), 12)), seed=13)
    eager = sharded_multisketch(spec, mesh, keys, w)
    eng = SegmentQueryEngine.from_sharded(spec, mesh, keys, w)
    lazy = eng.merged
    same = all(bool(np.array_equal(np.asarray(a), np.asarray(b)))
               for a, b in zip(lazy, eager))
    est = eng.query_many(predicates=[C.EVERYTHING,
                                     C.key_range(0, n // 2 - 1)])
    ok_est = abs(est[0, 0] / w.sum() - 1) < 0.5
    print("RESULT " + json.dumps({"same": bool(same),
                                  "est_ok": bool(ok_est)}))
""")


def test_engine_from_sharded_matches_eager_multidevice():
    """Lazy merge-on-demand over real per-device shards is bit-identical
    to the eager replicated sharded_multisketch re-selection."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    assert json.loads(line[len("RESULT "):]) == {"same": True,
                                                 "est_ok": True}


# ------------------------------------------------ satellites
@pytest.mark.parametrize("n,k1,quantize", [
    (1000, 17, False), (700, 65, True), (256, 5, True), (50, 65, False),
    (513, 8, True), (2048, 129, True), (1, 3, False)])
def test_blocked_buffer_scan_bit_identical(n, k1, quantize):
    from repro.core.universal import _buffer_scan, _buffer_scan_ref
    rng = np.random.default_rng(n + k1)
    v = rng.exponential(1.0, n).astype(np.float32)
    if quantize:
        v = np.round(v * 8) / 8            # heavy value ties
    v[rng.random(n) > 0.9] = np.inf        # inactive sentinels mid-stream
    idx = rng.permutation(n).astype(np.int32)
    got = _buffer_scan(jnp.asarray(v), jnp.asarray(idx), k1)
    want = _buffer_scan_ref(jnp.asarray(v), jnp.asarray(idx), k1)
    for name, g, r in zip(("rank", "tail_v", "tail_i"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                      err_msg=name)


def test_blocked_buffer_scan_overflow_fallback():
    """Descending input saturates the inserted-subsequence bound; the
    lax.cond fallback must keep the result exact."""
    from repro.core.universal import _buffer_scan, _buffer_scan_ref
    rng = np.random.default_rng(0)
    v = np.sort(rng.exponential(1.0, 4096).astype(np.float32))[::-1].copy()
    idx = np.arange(4096, dtype=np.int32)
    got = _buffer_scan(jnp.asarray(v), jnp.asarray(idx), 9)
    want = _buffer_scan_ref(jnp.asarray(v), jnp.asarray(idx), 9)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_blocked_buffer_scan_tie_eviction_at_capacity():
    """Ties AT the capacity boundary: an incoming value equal to the
    buffer tail still inserts (rank counts strictly smaller only) and
    evicts the old tail, so tail_v repeats while tail_i changes. The
    blocked scan must reproduce the reference's evict-last choice
    bit-exactly, including which index the emitted tail carries."""
    from repro.core.universal import _buffer_scan, _buffer_scan_ref
    rng = np.random.default_rng(7)
    # a 4-value alphabet over 1500 draws: the tail is almost always tied
    v = rng.choice(np.array([1.0, 2.0, 3.0, 4.0], np.float32), 1500)
    idx = np.arange(1500, dtype=np.int32)
    for k1 in (3, 17, 64):
        got = _buffer_scan(jnp.asarray(v), jnp.asarray(idx), k1)
        want = _buffer_scan_ref(jnp.asarray(v), jnp.asarray(idx), k1)
        for name, g, r in zip(("rank", "tail_v", "tail_i"), got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                          err_msg=f"k1={k1} {name}")
    # the emitted tail index must actually churn under ties (the evict
    # path runs), not just repeat the first tail forever
    ti = np.asarray(_buffer_scan(jnp.asarray(v), jnp.asarray(idx), 3)[2])
    assert len(set(ti[np.asarray(v) == 4.0].tolist())) > 1


def test_blocked_buffer_scan_all_equal_forces_full_replay():
    """All-equal values: every rank is 0, the whole stream 'inserts', the
    inserted-subsequence bound overflows and the lax.cond falls back to
    the full sequential replay — which must stay exact under total ties."""
    from repro.core.universal import (_buffer_scan, _buffer_scan_ref,
                                      _insert_bound)
    n, k1 = 4096, 9
    assert _insert_bound(n, k1) < n     # the compressed path CAN'T hold it
    v = np.full(n, 2.5, np.float32)
    idx = np.arange(n, dtype=np.int32)
    got = _buffer_scan(jnp.asarray(v), jnp.asarray(idx), k1)
    want = _buffer_scan_ref(jnp.asarray(v), jnp.asarray(idx), k1)
    for name, g, r in zip(("rank", "tail_v", "tail_i"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                      err_msg=name)


def test_merge_sketches_jit_cached_and_donatable():
    from repro.core.merge import _merge_jit
    rng = np.random.default_rng(1)
    n, k = 2000, 32
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1, n).astype(np.float32)
    act = np.ones(n, bool)
    cap = C.sketch_capacity(n, k)
    a = C.build_sketch(keys[:n // 2], w[:n // 2], act[:n // 2], k, cap, 0)
    b = C.build_sketch(keys[n // 2:], w[n // 2:], act[n // 2:], k, cap, 0)
    m0 = C.merge_sketches(a, b)
    misses = _merge_jit._cache_size()
    for _ in range(3):
        m1 = C.merge_sketches(a, b)
    assert _merge_jit._cache_size() == misses, "merge retraced per call"
    assert isinstance(m1.k, int) and m1.k == k   # static fields stay static
    # donated variant: same result from fresh (device-committed) copies
    fresh = lambda s: s._replace(
        keys=jnp.array(s.keys), weights=jnp.array(s.weights),
        probs=jnp.array(s.probs), member=jnp.array(s.member),
        valid=jnp.array(s.valid))
    m2 = C.merge_sketches(fresh(a), fresh(b), donate=True)
    np.testing.assert_array_equal(np.asarray(m0.keys), np.asarray(m2.keys))
    np.testing.assert_array_equal(np.asarray(m0.probs), np.asarray(m2.probs))


def test_query_many_B1_single_query_fast_path(monkeypatch):
    """Satellite regression pin (bench_query_engine_B1_F1 showed 0.5x):
    a B=1 query must run a ONE-row table — the same work unit as the
    one-query-at-a-time loop, sharing its jit-cached executable — while
    B in (1, b_quantum] still pads to the bucket."""
    import repro.core.multi_sketch as MS
    spec = C.MultiSketchSpec(objectives=_objectives(2), seed=15)
    eng = SegmentQueryEngine(spec)
    eng.absorb(np.arange(400), np.ones(400, np.float32))
    widths = []
    real = MS.multisketch_estimate_batch

    def spy(sk, fs, table, use_kernels=None):
        widths.append(np.asarray(table).shape[0])
        return real(sk, fs, table, use_kernels=use_kernels)

    monkeypatch.setattr(MS, "multisketch_estimate_batch", spy)
    single = eng.query(C.SUM, C.key_range(0, 199))
    assert widths[-1] == 1, "B=1 padded to a wider bucket"
    out5 = eng.query_many((C.SUM,), _predicates(5))
    assert widths[-1] == eng.b_quantum, "B in (1, quantum] must bucket"
    # same executable as the loop path's 1-predicate estimate: no retrace
    misses = MS._estimate_batch_jit._cache_size()
    loop = float(np.asarray(real(eng.merged, (C.SUM,),
                                 (C.key_range(0, 199),)))[0, 0])
    assert MS._estimate_batch_jit._cache_size() == misses
    assert abs(single - loop) <= 1e-5 * max(1.0, abs(loop))
    assert out5.shape == (1, 5)
    # B=0 (pre-encoded empty table) still buckets and returns empty
    out0 = eng.query_many((C.SUM,), np.zeros((0, PRED_COLS), np.int32))
    assert out0.shape == (1, 0)
    assert widths[-1] == eng.b_quantum


def test_collector_routes_queries_through_batched_path():
    from repro.telemetry.stats import StatsCollector, TelemetryConfig
    rng = np.random.default_rng(0)
    tel = StatsCollector(TelemetryConfig(k=48, capacity=512, seed=9))
    w = rng.lognormal(0, 1, 700).astype(np.float32)
    tel.absorb(np.arange(700), w)
    # predicate and callable segment paths agree
    q_pred = tel.query(C.SUM, C.key_range(0, 349))
    q_call = tel.query(C.SUM, segment_fn=lambda k: k < 350)
    assert abs(q_pred - q_call) <= 1e-3 * max(1.0, abs(q_call))
    qm = tel.query_many((C.SUM, C.COUNT),
                        (C.EVERYTHING, C.key_range(0, 349)))
    assert qm.shape == (2, 2)
    assert abs(qm[0, 0] - tel.query(C.SUM)) <= 1e-3 * abs(qm[0, 0])
    # repeated single queries reuse one executable (batched jit path)
    from repro.core.multi_sketch import _estimate_batch_jit
    misses = _estimate_batch_jit._cache_size()
    for _ in range(4):
        tel.query(C.SUM)
    assert _estimate_batch_jit._cache_size() == misses
