"""Merge-dispatch spy: counts of the engine's merge-work dispatches.

Pytest-free on purpose — the bench-smoke CI job imports this module from
`benchmarks/run.py` (an environment with jax+numpy only) to assert the
zero-merge serving claim: a query phase under absorb-time maintenance
must dispatch NO merge work (``full == inc == 0``), because every chunk
was already folded into the cached merged slab at absorb time.

The spy wraps the two query-path merge entry points in ``launch.query``:
``_full_remerge`` (the full stacked re-merge, itself a fold into a fresh
empty slab) and ``multisketch_absorb_slabs`` (the incremental delta
fold). Both are resolved through the module globals, so patching the
module attributes captures every engine-internal call; while a spied
full re-merge delegates, the inner fold is un-spied so it counts as one
"full", not full+inc. Note ``gc_apply``/``add_shard`` also route through
``multisketch_absorb_slabs`` — scope the context manager around the
phase being measured (the query loop), not the whole run, to count
query-time dispatches only.
"""
from contextlib import contextmanager

from repro.launch import query as Q


@contextmanager
def spy_merge_dispatch():
    """Context manager yielding a live ``{"full": n, "inc": n}`` counter
    of merge dispatches issued while the context is active."""
    counts = {"full": 0, "inc": 0}
    real_full = Q._full_remerge
    real_into = Q.multisketch_absorb_slabs

    def spy_full(*a, **k):
        counts["full"] += 1
        Q.multisketch_absorb_slabs = real_into
        try:
            return real_full(*a, **k)
        finally:
            Q.multisketch_absorb_slabs = spy_into

    def spy_into(*a, **k):
        counts["inc"] += 1
        return real_into(*a, **k)

    Q._full_remerge = spy_full
    Q.multisketch_absorb_slabs = spy_into
    try:
        yield counts
    finally:
        Q._full_remerge = real_full
        Q.multisketch_absorb_slabs = real_into
