"""Hypothesis property tests on the sampling system's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core as C  # noqa: E402

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


weights_strategy = st.lists(
    st.floats(min_value=0.0009765625, max_value=16384.0, allow_nan=False,
              allow_infinity=False, width=32),
    min_size=4, max_size=120)


@given(weights_strategy, st.integers(1, 12), st.integers(0, 10_000))
def test_monotone_membership_iff_h_less_k(ws, k, seed):
    """Lemma 5.1: x in S^(M,k) <=> h_x < k — on arbitrary weights."""
    w = np.array(ws, np.float32)
    n = len(w)
    keys = np.arange(n, dtype=np.int32)
    act = np.ones(n, bool)
    u = np.asarray(C.uniform01(keys, seed))
    s = C.universal_monotone_ref(w, u, act, k)
    h = ((w[None, :] >= w[:, None]) & (u[None, :] < u[:, None])).sum(1)
    np.testing.assert_array_equal(np.asarray(s.member), h < k)


@given(weights_strategy, st.integers(1, 8), st.integers(0, 10_000))
def test_monotone_contains_every_dedicated_bottomk(ws, k, seed):
    """Lemma 5.2: S^(M,k) ⊇ bottom-k sample for ANY monotone f."""
    w = np.array(ws, np.float32)
    n = len(w)
    keys = np.arange(n, dtype=np.int32)
    act = np.ones(n, bool)
    uni = C.universal_monotone_sample(keys, w, act, k, seed=seed)
    med = float(np.median(w))
    for f in [C.SUM, C.COUNT, C.thresh(med), C.cap(med), C.moment(2.0)]:
        ded = C.bottomk_sample(keys, w, act, f, k, "ppswor", seed=seed)
        assert bool(jnp.all(ded.member <= uni.member)), f.name


@given(weights_strategy, st.integers(1, 8), st.integers(0, 1000))
def test_probs_are_valid_probabilities(ws, k, seed):
    w = np.array(ws, np.float32)
    keys = np.arange(len(w), dtype=np.int32)
    act = np.ones(len(w), bool)
    s = C.universal_monotone_sample(keys, w, act, k, seed=seed)
    p = np.asarray(s.prob)
    m = np.asarray(s.member)
    assert np.all(p[m] > 0) and np.all(p[m] <= 1.0 + 1e-6)
    assert np.all(p[~m] == 0)
    # estimator nonnegative & zero outside sample (paper Eq. 2)
    est = C.estimate(C.SUM, w, s.prob, s.member)
    assert float(est) >= 0


@given(weights_strategy, st.integers(2, 8), st.integers(0, 1000),
       st.integers(2, 5))
def test_merge_is_associative_and_order_free(ws, k, seed, nparts):
    w = np.array(ws, np.float32)
    n = len(w)
    keys = np.arange(n, dtype=np.int32)
    act = np.ones(n, bool)
    cap_sz = C.sketch_capacity(n, k)
    parts = np.array_split(np.arange(n), min(nparts, n))

    def member_set(sk):
        return {(int(a), round(float(p), 5)) for a, p, m, v in
                zip(sk.keys, sk.probs, sk.member, sk.valid) if v and m}

    sks = [C.build_sketch(keys[p], w[p], act[p], k, cap_sz, seed=seed)
           for p in parts if len(p)]
    fwd = sks[0]
    for s in sks[1:]:
        fwd = C.merge_sketches(fwd, s)
    rev = sks[-1]
    for s in reversed(sks[:-1]):
        rev = C.merge_sketches(rev, s)
    whole = C.build_sketch(keys, w, act, k, cap_sz, seed=seed)
    assert member_set(fwd) == member_set(rev) == member_set(whole)


@given(weights_strategy, st.integers(0, 1000))
def test_coordination_nesting(ws, seed):
    """Coordinated bottom-k samples are nested in k (same randomization)."""
    w = np.array(ws, np.float32)
    keys = np.arange(len(w), dtype=np.int32)
    act = np.ones(len(w), bool)
    prev = None
    for k in (1, 2, 4, 8):
        s = C.bottomk_sample(keys, w, act, C.SUM, k, seed=seed)
        if prev is not None:
            assert bool(jnp.all(prev <= s.member))
        prev = s.member


@given(st.lists(st.floats(min_value=0.5, max_value=100, width=32),
                min_size=8, max_size=64),
       st.integers(1, 6), st.integers(0, 500))
def test_capping_membership_iff_hl_less_k(ws, k, seed):
    """Lemma 6.3 on arbitrary inputs (ref vs first-principles count)."""
    w = np.array(ws, np.float32)
    n = len(w)
    keys = np.arange(n, dtype=np.int32)
    act = np.ones(n, bool)
    u = np.asarray(C.uniform01(keys, seed))
    r = np.asarray(C.ppswor_rank(u))
    s = C.universal_capping_ref(w, u, act, k)
    h = ((w[None, :] >= w[:, None]) & (u[None, :] < u[:, None])).sum(1)
    rw = r / w
    l = ((w[None, :] < w[:, None]) & (rw[None, :] < rw[:, None])).sum(1)
    np.testing.assert_array_equal(np.asarray(s.member), (h + l) < k)
