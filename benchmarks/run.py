"""Benchmark harness — one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper's table/theorem is about) and mirrors every row into
``BENCH_results.json`` ({name: us_per_call} plus derived strings) so the
perf trajectory is machine-readable across PRs.
Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import math
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as C
import repro.kernels as K

RESULTS: dict[str, float] = {}      # name -> us_per_call
DERIVED: dict[str, str] = {}        # name -> derived string


def _record(name: str, us: float, derived: str = ""):
    RESULTS[name] = round(float(us), 3)
    DERIVED[name] = derived
    print(f"{name},{us:.1f},{derived}")


def _timeit(fn, n=5):
    fn()  # warmup/compile
    t0 = time.perf_counter()
    out = None
    for _ in range(n):
        out = fn()
    if out is not None:
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_example_2_1_pps_table():
    """Paper Example 2.1: pps probabilities for sum/thresh/cap, k=3."""
    w = np.array([5, 100, 23, 7, 1, 5, 220, 19, 3, 2], np.float32)
    act = np.ones(10, bool)
    us = _timeit(lambda: [C.pps_probabilities(w, act, f, 3)[0]
                          for f in (C.SUM, C.thresh(10), C.cap(5))][0])
    p_sum, s = C.pps_probabilities(w, act, C.SUM, 3)
    _record("example_2_1_pps_table", us, f"total_sum={float(s):g}")


def bench_example_3_1_multiobjective_size():
    """Paper Example 3.1: E|S^(F)| vs naive union of dedicated samples."""
    w = np.array([5, 100, 23, 7, 1, 5, 220, 19, 3, 2], np.float32)
    act = np.ones(10, bool)
    objs = [(C.SUM, 3), (C.thresh(10), 3), (C.cap(5), 3)]

    def run():
        probs = [C.pps_probabilities(w, act, f, k)[0] for f, k in objs]
        return jnp.stack(probs).max(0).sum(), sum(p.sum() for p in probs)
    us = _timeit(lambda: run()[0])
    e_sf, naive = run()
    _record("example_3_1_multiobjective_size", us,
            f"E_SF={float(e_sf):.3f};naive={float(naive):.3f};paper=4.68/8.29")


def bench_thm_5_1_universal_size():
    """Thm 5.1: E|S^(M,k)| <= k ln n (+ Thm 5.2 lower bound shape)."""
    k = 16
    rows = []
    for n in (1_000, 10_000, 100_000):
        keys = np.arange(n, dtype=np.int32)
        w = np.random.default_rng(0).lognormal(0, 2, n).astype(np.float32)
        act = np.ones(n, bool)
        sizes = [int(C.universal_monotone_sample(keys, w, act, k,
                                                 seed=s).member.sum())
                 for s in range(8)]
        us = _timeit(lambda: C.universal_monotone_sample(keys, w, act, k,
                                                         seed=0).member)
        bound = k * math.log(n)
        lower = k * (math.log(n) - math.log(k))  # Thm 5.2 Omega(k ln n)
        rows.append((n, np.mean(sizes), bound, lower, us))
        _record(f"thm5_1_universal_size_n{n}", us,
                f"mean={np.mean(sizes):.1f};kln_n={bound:.1f};"
                f"lower={lower:.1f}")
    g1 = rows[1][1] / rows[0][1]
    g2 = rows[2][1] / rows[1][1]
    _record("thm5_1_log_growth", 0.0,
            f"size_ratio_per_10x={g1:.2f}/{g2:.2f}"
            f";expected_if_log={math.log(10_000)/math.log(1_000):.2f}")


def bench_thm_6_1_capping_size():
    """Thm 6.1: E|S^(C,k)| <= e k ln(w_max/w_min), independent of n."""
    k = 16
    rng = np.random.default_rng(1)
    for n in (1_000, 10_000, 100_000):
        keys = np.arange(n, dtype=np.int32)
        w = np.clip(rng.lognormal(0, 1.0, n), 0.1, 10.0).astype(np.float32)
        act = np.ones(n, bool)
        sizes = [int(C.universal_capping_sample(
            keys, w, act, k, m_cap=4096, seed=s).member.sum())
            for s in range(5)]
        us = _timeit(lambda: C.universal_capping_sample(
            keys, w, act, k, m_cap=4096, seed=0).member)
        bound = C.capping_size_bound(k, 10.0, 0.1)
        _record(f"thm6_1_capping_size_n{n}", us,
                f"mean={np.mean(sizes):.1f};bound={bound:.1f}")


def bench_thm_3_1_estimation_cv():
    """Thm 3.1/§5.1: empirical CV vs gold-standard bound per f (segment)."""
    n, k, trials = 2_000, 24, 200
    rng = np.random.default_rng(2)
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    act = np.ones(n, bool)
    seg = (np.arange(n) % 4 == 0)
    for f in [C.SUM, C.COUNT, C.thresh(3.0), C.cap(2.0), C.moment(1.5)]:
        t0 = time.perf_counter()
        ests = [float(C.estimate(f, w, s.prob, s.member, seg))
                for s in (C.universal_monotone_sample(keys, w, act, k, seed=i)
                          for i in range(trials))]
        us = (time.perf_counter() - t0) * 1e6 / trials
        ex = float(C.exact(f, w, act, seg))
        q = ex / float(C.exact(f, w, act))
        cv = float(np.std(ests) / ex)
        bound = C.cv_bound(q, k)
        _record(f"thm3_1_cv_{f.name}", us,
                f"cv={cv:.3f};bound={bound:.3f};ok={cv <= bound}")


def bench_sampling_throughput():
    """Production sort+scan vs fused kernels (keys/s)."""
    n, k = 65_536, 64
    rng = np.random.default_rng(3)
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    act = np.ones(n, bool)
    us_prod = _timeit(lambda: C.universal_monotone_sample(
        keys, w, act, k, seed=0).member)
    _record("throughput_universal_sortscan", us_prod,
            f"keys_per_s={n/us_prod*1e6:.3g};seed_recorded=3.18e5;"
            f"speedup_vs_seed={n/us_prod*1e6/3.18e5:.2f}x")
    objs = ((0, 0.0), (3, 2.0), (1, 0.0))
    us_k = _timeit(lambda: K.ops.multi_objective_bottomk_kernel(
        jnp.asarray(keys), jnp.asarray(w), jnp.asarray(act), objs, k)[0])
    _record("throughput_multiobj_kernel", us_k,
            f"keys_per_s={n/us_k*1e6:.3g};note=interpret_mode_cpu")


def bench_merge_throughput():
    """Composability cost: sketch merge (paper §5.2) at fixed capacity.

    Satellite fix: merge_sketches is now jit-cached per (k, capacity, seed)
    with an opt-in both-inputs-donated variant; the un-jitted op-by-op
    dispatch path (the seed's behavior, 131.8 ms/call recorded pre-fix) is
    timed alongside as the before/after record.
    """
    from repro.core.merge import _rebuild
    n, k = 16_384, 32
    rng = np.random.default_rng(4)
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    act = np.ones(n, bool)
    cap_sz = C.sketch_capacity(n, k)
    a = C.build_sketch(keys[:n // 2], w[:n // 2], act[:n // 2], k, cap_sz, 0)
    b = C.build_sketch(keys[n // 2:], w[n // 2:], act[n // 2:], k, cap_sz, 0)

    def merge_nojit():
        return _rebuild(jnp.concatenate([a.keys, b.keys]),
                        jnp.concatenate([a.weights, b.weights]),
                        jnp.concatenate([a.valid, b.valid]),
                        k, cap_sz, 0).member

    us_nojit = _timeit(merge_nojit)
    us = _timeit(lambda: C.merge_sketches(a, b).member)
    _record("merge_sketches", us,
            f"capacity={cap_sz};nojit_us={us_nojit:.0f};"
            f"seed_recorded_us=131789;jit_speedup={us_nojit/us:.1f}x")
    # donated fold: state <- merge(state, fresh) with both slabs consumed
    fresh = lambda s: s._replace(
        keys=jnp.array(s.keys), weights=jnp.array(s.weights),
        probs=jnp.array(s.probs), member=jnp.array(s.member),
        valid=jnp.array(s.valid))
    pool = [(fresh(a), fresh(b)) for _ in range(7)]
    it = iter(pool)
    import warnings
    with warnings.catch_warnings():
        # int32 keys can't alias across the concat; donation of the float
        # slabs still holds — silence the partial-donation notice
        warnings.filterwarnings("ignore", message=".*donated buffers.*")
        us_don = _timeit(
            lambda: C.merge_sketches(*next(it), donate=True).member, n=5)
    _record("merge_sketches_donated", us_don, f"capacity={cap_sz}")


def bench_universal_scan(smoke: bool = False):
    """Satellite: the blocked buffer scan (rank pass + inserted-subsequence
    replay) vs the sequential one-element-per-step reference scan. Runs at
    full n even in --smoke: the blocked win is the large-n regime (the
    inserted-subsequence bound grows ~k ln n while n grows linearly)."""
    from repro.core.universal import _buffer_scan, _buffer_scan_ref
    n, k1 = 65_536, 65
    rng = np.random.default_rng(8)
    v = jnp.asarray(rng.exponential(1.0, n).astype(np.float32))
    idx = jnp.arange(n, dtype=jnp.int32)
    ref = jax.jit(partial(_buffer_scan_ref, k_plus_1=k1))
    us_blk = _timeit(lambda: _buffer_scan(v, idx, k1)[1])
    us_ref = _timeit(lambda: ref(v, idx)[1])
    _record("universal_scan_blocked", us_blk,
            f"keys_per_s={n/us_blk*1e6:.3g};"
            f"speedup_vs_ref={us_ref/us_blk:.2f}x")
    _record("universal_scan_ref", us_ref, f"keys_per_s={n/us_ref*1e6:.3g}")


def bench_query_engine(smoke: bool = False):
    """Tentpole claim: batched segment queries (ONE fused launch for
    B predicates x |F| objectives, kernels.segquery) vs the one-query-at-
    a-time loop (one launch per (f, H) pair — the pre-PR serving path),
    against a resident merged slab. queries/s, B x |F| grid."""
    from repro.launch.query import SegmentQueryEngine
    pool = ((C.SUM, 64), (C.COUNT, 64), (C.thresh(2.0), 64),
            (C.cap(1.5), 64), (C.moment(1.5), 64), (C.thresh(0.5), 64),
            (C.cap(4.0), 64), (C.moment(0.5), 64))
    n = 16_384 if smoke else 65_536
    rng = np.random.default_rng(9)
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    grid = (((1, 1), (16, 3), (128, 8)) if smoke
            else ((1, 1), (1, 3), (1, 8), (16, 1), (16, 3), (16, 8),
                  (128, 1), (128, 3), (128, 8)))
    span = n // 128

    for b, nf in grid:
        spec = C.MultiSketchSpec(objectives=pool[:nf], seed=0)
        eng = SegmentQueryEngine(spec, shards=4)
        for i in range(4):
            eng.absorb(keys[i::4], w[i::4], shard=i)
        preds = [C.key_range(j * span, (j + 1) * span - 1) for j in range(b)]
        fs = tuple(f for f, _ in spec.objectives)
        sk = eng.merged

        us_batch = _timeit(lambda: eng.query_many(fs, preds), n=3)
        qps_batch = b * nf / us_batch * 1e6

        def loop_all():
            out = None
            for f in fs:
                for p in preds:
                    # a per-query serving loop delivers each answer to the
                    # host before the next request (same sync discipline
                    # query_many's numpy return pays once per batch)
                    out = np.asarray(C.multisketch_estimate_batch(sk, (f,),
                                                                  (p,)))
            return out
        us_loop = _timeit(loop_all, n=3)
        qps_loop = b * nf / us_loop * 1e6
        _record(f"bench_query_engine_B{b}_F{nf}", us_batch,
                f"qps={qps_batch:.3g};loop_qps={qps_loop:.3g};"
                f"batched_speedup={us_loop/us_batch:.1f}x")


def bench_cluster_engine(smoke: bool = False):
    """PR 4 tentpole claim: batched service-cost scoring (ONE fused launch
    for Q candidate center sets x the resident sample slab,
    kernels.servicecost) vs the one-set-at-a-time loop (one launch per
    candidate — the host-loop scoring a swap search would otherwise pay),
    over a Q x |C| grid."""
    from repro.core.costs import cost_table
    from repro.launch.cluster import ClusterEngine

    n, dim = (4096 if smoke else 16384), 8
    rng = np.random.default_rng(10)
    ctrs = rng.normal(0, 6, (8, dim))
    X = (ctrs[rng.integers(0, 8, n)]
         + rng.normal(0, 0.7, (n, dim))).astype(np.float32)
    eng = ClusterEngine.fit(X, k=64, mu=2.0, seed=0)
    grid = (((16, 8), (128, 8), (128, 64)) if smoke
            else ((1, 8), (16, 8), (16, 64), (128, 8), (128, 64)))
    for q, cm in grid:
        sets = X[rng.integers(0, n, (q, cm))]
        table = cost_table(sets, 2.0)
        us_batch = _timeit(lambda: eng.service_costs(table), n=3)
        rows = [cost_table(sets[i:i + 1], 2.0) for i in range(q)]

        def loop_all():
            out = None
            for r in rows:
                out = eng.service_costs(r)
            return out
        us_loop = _timeit(loop_all, n=3)
        _record(f"bench_cluster_engine_Q{q}_C{cm}", us_batch,
                f"sets_per_s={q/us_batch*1e6:.3g};"
                f"loop_sets_per_s={q/us_loop*1e6:.3g};"
                f"batched_speedup={us_loop/us_batch:.1f}x")


def bench_engine_tail_latency(smoke: bool = False):
    """PR 7 tentpole: query-engine tail latency under interleaved
    absorb/query. With absorb-time maintenance (the default) the merged
    slab is folded forward DURING the absorb, so the churn-phase query
    path dispatches ZERO merge work — asserted by the dispatch spy
    (query_time_folds must be 0) — and churn_tax_p50 collapses to ~1x.
    p50/p95/max per-query microseconds."""
    from repro.launch.query import SegmentQueryEngine
    from tests.dispatch_spy import spy_merge_dispatch
    spec = C.MultiSketchSpec(objectives=((C.SUM, 64), (C.COUNT, 64),
                                         (C.thresh(2.0), 64)), seed=0)
    n = 8192 if smoke else 32768
    iters = 16 if smoke else 32
    rng = np.random.default_rng(11)
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    preds = [C.key_range(j * (n // 16), (j + 1) * (n // 16) - 1)
             for j in range(16)]
    fs = tuple(f for f, _ in spec.objectives)

    eng = SegmentQueryEngine(spec, shards=2)
    eng.absorb(keys[::2], w[::2], shard=0)
    eng.absorb(keys[1::2], w[1::2], shard=1)
    # warm every executable in the chain (the bootstrap full merge, the
    # absorb-time fold, the fused query launch)
    eng.query_many(fs, preds)
    eng.absorb(keys[:1], w[:1], shard=0)
    eng.query_many(fs, preds)

    # churn and steady samples INTERLEAVED in one loop: each epoch's
    # first query (right after the absorb) is the churn sample, and an
    # immediate second query — a pure cache hit on the identical state —
    # is the steady baseline. Pairing them under the same machine
    # conditions is what makes the ratio a property of the engine, not
    # of CPU-frequency / scheduler drift between two separate phases.
    churn, steady = [], []
    folds = {"full": 0, "inc": 0}
    stats0 = dict(eng.merge_stats)
    for i in range(iters):
        eng.absorb(keys[i::iters], w[i::iters], shard=i % 2)
        # drain the absorb epoch (shard fold + merged-slab maintenance +
        # probs finalize are async-dispatched): maintenance cost is
        # charged to absorb time, where it now runs — the query timer
        # below must measure the query launch, not the previous epoch's
        # device backlog (a serving pump drains folds between requests
        # the same way)
        eng.drain()
        with spy_merge_dispatch() as counts:
            t0 = time.perf_counter()
            eng.query_many(fs, preds)
            churn.append((time.perf_counter() - t0) * 1e6)
            t0 = time.perf_counter()
            eng.query_many(fs, preds)
            steady.append((time.perf_counter() - t0) * 1e6)
        folds["full"] += counts["full"]
        folds["inc"] += counts["inc"]
    churn, steady = np.asarray(churn), np.asarray(steady)
    at = eng.merge_stats["absorb_time"] - stats0["absorb_time"]
    query_time_folds = folds["full"] + folds["inc"]
    _record("engine_tail_latency_churn", float(np.percentile(churn, 95)),
            f"p50={np.percentile(churn, 50):.0f};"
            f"p95={np.percentile(churn, 95):.0f};max={churn.max():.0f};"
            f"steady_p50={np.percentile(steady, 50):.0f};"
            f"steady_p95={np.percentile(steady, 95):.0f};"
            f"query_time_folds={query_time_folds};absorb_time_folds={at};"
            f"churn_tax_p50={np.percentile(churn, 50)/max(np.percentile(steady, 50), 1e-9):.2f}x")


def bench_incremental_merge(smoke: bool = False):
    """PR 5 tentpole: epoch maintenance cost when ONE shard absorbed — the
    delta fold into the cached merged slab (multisketch_absorb_into,
    donated buffers, (1 + dirty) x capacity re-selection) vs the full
    stacked re-merge over all S shards. The gap widens with S: the full
    path stacks and rebuilds S x capacity slots every epoch."""
    from repro.launch.query import SegmentQueryEngine
    spec = C.MultiSketchSpec(objectives=((C.SUM, 64), (C.COUNT, 64),
                                         (C.thresh(2.0), 64)), seed=0)
    n = 8192 if smoke else 32768
    rng = np.random.default_rng(12)
    keys = np.arange(n, dtype=np.int32)
    w = rng.lognormal(0, 1.5, n).astype(np.float32)
    for shards in ((2, 8) if smoke else (2, 4, 8)):
        # lazy twins isolate the PR 5 ladder; the third engine runs the
        # PR 7 absorb-time maintenance (same fold, paid inside absorb)
        engs = {"incremental": SegmentQueryEngine(spec, shards=shards,
                                                  absorb_time=False),
                "full": SegmentQueryEngine(spec, shards=shards,
                                           absorb_time=False, max_delta=0),
                "absorb_time": SegmentQueryEngine(spec, shards=shards)}
        for eng in engs.values():
            for i in range(shards):
                eng.absorb(keys[i::shards], w[i::shards], shard=i)
            eng._materialize_merged()
        us = {}
        for name, eng in engs.items():
            def epoch(i=[0], eng=eng):
                i[0] += 1
                eng.absorb(keys[i[0] % 7::7], w[i[0] % 7::7],
                           shard=i[0] % shards)
                return eng._materialize_merged().member
            epoch()  # warm the per-path executables
            us[name] = _timeit(epoch, n=5)
        _record(f"incremental_merge_S{shards}", us["incremental"],
                f"full_us={us['full']:.0f};"
                f"absorb_time_us={us['absorb_time']:.0f};"
                f"speedup={us['full']/us['incremental']:.1f}x")


def bench_shard_gc(smoke: bool = False):
    """PR 7 shard lifecycle: long-run churn under the auto GC water-mark.
    Reports the GC merge cost, the live-shard plateau and the resident-
    bytes bound — the O(capacity)-memory claim for long-running streams
    (CI asserts the plateau fields exist and live <= water-mark)."""
    from repro.launch.query import SegmentQueryEngine
    spec = C.MultiSketchSpec(objectives=((C.SUM, 64), (C.COUNT, 64),
                                         (C.thresh(2.0), 64)), seed=0)
    epochs = 24 if smoke else 64
    shards, water = 8, 3
    chunk = 2048 if smoke else 8192
    rng = np.random.default_rng(13)
    eng = SegmentQueryEngine(spec, shards=shards, gc_max_live=water)
    gc_us, live_track, bytes_track = [], [], []
    for i in range(epochs):
        k = rng.integers(0, 1 << 20, chunk).astype(np.int32)
        w = rng.lognormal(0, 1.5, chunk).astype(np.float32)
        gc0 = eng.merge_stats["gc_merges"]
        t0 = time.perf_counter()
        eng.absorb(k, w, shard=int(rng.integers(0, shards)))
        us = (time.perf_counter() - t0) * 1e6
        if eng.merge_stats["gc_merges"] > gc0:
            gc_us.append(us)
        live_track.append(eng.merge_stats["live_shards"])
        bytes_track.append(eng.merge_stats["bytes_resident"])
    jax.block_until_ready(eng.merged.keys)
    half = epochs // 2
    _record("bench_shard_gc",
            float(np.mean(gc_us)) if gc_us else 0.0,
            f"gc_merges={eng.merge_stats['gc_merges']};"
            f"live_max={max(live_track)};live_plateau={max(live_track[half:])};"
            f"water_mark={water};"
            f"bytes_plateau={max(bytes_track[half:])};"
            f"bytes_peak={max(bytes_track)};"
            f"plateau_bounded={int(max(bytes_track[half:]) <= max(bytes_track[:half]))}")


def bench_absorb_throughput(smoke: bool = False):
    """Tentpole claim: the jit'd device-resident MultiSketch fold vs the
    seed's host-side per-batch rebuild-and-merge absorption loop
    (build_sketch + merge_sketches per chunk), capacity >= 1024."""
    k, capacity = 64, 1024
    chunk = 1024 if smoke else 4096
    iters = 4 if smoke else 12
    rng = np.random.default_rng(7)
    ws = [rng.lognormal(0, 1, chunk).astype(np.float32)
          for _ in range(iters)]
    ks = [(i * chunk + np.arange(chunk)).astype(np.int32)
          for i in range(iters)]
    act = np.ones(chunk, bool)

    spec = C.MultiSketchSpec(objectives=((C.SUM, k), (C.COUNT, k)),
                             seed=0, capacity=capacity)

    def fold_all():
        st = C.multisketch_empty(spec)
        for i in range(iters):
            st = C.multisketch_absorb(st, ks[i], ws[i], spec=spec,
                                      use_kernels=False)
        return st.member

    def host_rebuild_all():
        sk = None
        for i in range(iters):
            new = C.build_sketch(ks[i], ws[i], act, k, capacity, 0)
            sk = new if sk is None else C.merge_sketches(sk, new)
        return sk.member

    us_fold = _timeit(fold_all, n=3) / iters
    us_host = _timeit(host_rebuild_all, n=3) / iters
    _record("absorb_fold_device", us_fold,
            f"keys_per_s={chunk/us_fold*1e6:.3g};capacity={capacity}")
    _record("absorb_host_rebuild", us_host,
            f"keys_per_s={chunk/us_host*1e6:.3g};"
            f"fold_speedup={us_host/us_fold:.2f}x")


def bench_gradient_compression():
    """distopt: wire bytes vs dense, and estimate quality."""
    from repro.distopt.compression import _sample_leaf, _merge_leaf
    n, k = 262_144, 512
    rng = np.random.default_rng(5)
    g = (rng.standard_normal(n) * (rng.random(n) < 0.3)).astype(np.float32)
    us = _timeit(lambda: _sample_leaf(jnp.asarray(g), k, 7, 0.01).keys)
    sk = _sample_leaf(jnp.asarray(g), k, 7, 0.01)
    wire = int(sk.keys.size) * (4 + 4 + 4)
    dense = n * 4
    est = _merge_leaf(sk.keys[None], sk.weights[None], sk.probs[None],
                      sk.valid[None], n, 1)
    rel = float(jnp.linalg.norm(est - g) / jnp.linalg.norm(g))
    dots = float(jnp.dot(est, g) / jnp.dot(g, g))
    _record("grad_compression", us,
            f"ratio={dense/wire:.1f}x;l2rel={rel:.3f};proj={dots:.3f}")


_SCALING_POOL = ((0, 0.0), (3, 2.0), (1, 0.0), (2, 5.0),
                 (4, 1.5), (3, 0.5), (2, 1.0), (4, 0.8))


@partial(jax.jit, static_argnames=("objectives", "k"))
def _per_objective_loop(keys, weights, active, objectives, k):
    """The seed's multi-objective path: |F| separate block-select launches
    plus a per-objective StatFn/prob pass — the flat-vs-linear baseline."""
    from repro.core.bottomk import conditional_prob
    n = keys.shape[0]
    seeds = K.fused_seeds(keys, weights, active, objectives)
    member = jnp.zeros((n,), bool)
    prob = jnp.zeros((n,), jnp.float32)
    for j, (kind, param) in enumerate(objectives):
        vals, idx, tau = K.bottomk_select(seeds[j], k)
        m = (seeds[j] <= vals[k - 1]) & jnp.isfinite(seeds[j])
        fv = jnp.where(active,
                       K.ops.statfn_of(kind, param)(
                           jnp.asarray(weights, jnp.float32)), 0.0)
        p = jnp.where(m, conditional_prob(fv, tau, "ppswor"), 0.0)
        member = member | m
        prob = jnp.maximum(prob, p)
    return member, prob


def bench_multiobj_scaling():
    """Launch-cost scaling in |F|: fused single-launch chain vs the
    per-objective loop. The fused path should grow sublinearly (bandwidth
    term only); the loop pays |F| launches + 2|F| scans."""
    n, k = 65_536, 64
    rng = np.random.default_rng(6)
    keys = jnp.arange(n, dtype=jnp.int32)
    w = jnp.asarray(rng.lognormal(0, 1.5, n).astype(np.float32))
    act = jnp.ones(n, bool)
    base_fused = base_loop = None
    for nf in (1, 2, 4, 8):
        objs = _SCALING_POOL[:nf]
        us_f = _timeit(lambda: K.ops.multi_objective_bottomk_kernel(
            keys, w, act, objs, k)[0])
        us_l = _timeit(lambda: _per_objective_loop(keys, w, act, objs, k)[0])
        if base_fused is None:
            base_fused, base_loop = us_f, us_l
        _record(f"multiobj_scaling_F{nf}", us_f,
                f"fused_x={us_f/base_fused:.2f};loop_us={us_l:.1f};"
                f"loop_x={us_l/base_loop:.2f}")


def bench_serving_chaos(smoke: bool = False):
    """Robustness PR tentpole: the multi-tenant ``EnginePool`` under
    open-loop Poisson load WHILE a seeded fault schedule fires on the
    fold and query paths (device faults -> retry -> breaker -> last-good
    stale serving) and occasional producers ship NaN rows (quarantine).
    Latency is measured from the SCHEDULED arrival (queueing under
    overload is charged to the server). Reports p50/p95/p99 ms and
    availability = (FRESH + STALE) / total — the acceptance gate asserts
    availability >= 0.99 with every degraded answer labeled."""
    from repro.launch.pool import (FRESH, REJECTED, STALE, EnginePool,
                                   RejectedError)
    from tests.faults import FaultInjector, poisson_arrivals

    n_req = 100 if smoke else 400
    # smoke runs on CPU interpret-mode kernels: keep the offered load
    # below saturation so the percentiles measure the pool, not an
    # unpayable backlog
    rate_hz = 20.0 if smoke else 150.0
    rng = np.random.default_rng(20)
    # retries=0: each injected fault costs one op, so breakers actually
    # open under the 0.25 schedule (3 consecutive) and the bench walks
    # the whole ladder, not just the retry rung
    pool = EnginePool(queue_depth=256, retries=0, breaker_threshold=3,
                      breaker_reset=0.02, sleep=lambda s: None)
    # small per-objective k: the bench measures the POOL (admission,
    # ladder, breaker, quarantine), not kernel throughput — the query/
    # absorb benches above own that axis
    kk = 16 if smoke else 64
    spec = C.MultiSketchSpec(objectives=((C.SUM, kk), (C.COUNT, kk),
                                         (C.thresh(2.0), kk)), seed=0)
    fs = tuple(f for f, _ in spec.objectives)
    tenants = ("tenant_a", "tenant_b", "tenant_c")
    warm_n = 256 if smoke else 2048
    for i, name in enumerate(tenants):
        pool.create_stream(name, spec)
        keys = (i * 100_000 + np.arange(warm_n)).astype(np.int32)
        pool.absorb(name, keys,
                    rng.lognormal(0, 1.5, warm_n).astype(np.float32))
        pool.query(name, fs)            # warm the per-tenant executables

    arrivals = poisson_arrivals(rate_hz, n_req, rng)
    statuses = {FRESH: 0, STALE: 0, REJECTED: 0}
    lat_ms = []
    quarantined = 0
    t0 = time.perf_counter()
    with FaultInjector(seed=21) as inj:
        inj.fail_prob("query_merge", 0.25)
        inj.fail_prob("absorb_fold", 0.25)
        for i in range(n_req):
            sched = t0 + float(arrivals[i])
            while True:                 # open-loop: hold to the schedule
                gap = sched - time.perf_counter()
                if gap <= 0:
                    break
                time.sleep(min(gap, 1e-3))
            name = tenants[int(rng.integers(0, len(tenants)))]
            if i % 8 == 7:              # interleaved ingest under load
                keys = (500_000 + i * 64 + np.arange(64)).astype(np.int32)
                w = rng.lognormal(0, 1, 64).astype(np.float32)
                if i % 16 == 15:
                    w[::11] = np.nan    # corrupt producer rows
                try:
                    quarantined += pool.absorb(name, keys, w).quarantined
                except RejectedError:
                    pass
            try:
                fut = pool.submit(name, fs, timeout=2.0)
            except RejectedError:       # admission shed counts against us
                statuses[REJECTED] += 1
                continue
            pool.pump()
            resp = fut.result(5.0)
            statuses[resp.status] += 1
            lat_ms.append((time.perf_counter() - sched) * 1e3)
    lat = np.asarray(lat_ms)
    opens = sum(pool.stats(t)["breaker_opens"] for t in tenants)
    avail = (statuses[FRESH] + statuses[STALE]) / n_req
    _record("serving_chaos", float(np.percentile(lat, 95)) * 1e3,
            f"availability={avail:.4f};p50_ms={np.percentile(lat, 50):.2f};"
            f"p95_ms={np.percentile(lat, 95):.2f};"
            f"p99_ms={np.percentile(lat, 99):.2f};fresh={statuses[FRESH]};"
            f"stale={statuses[STALE]};rejected={statuses[REJECTED]};"
            f"quarantined={quarantined};breaker_opens={opens};"
            f"rate_hz={rate_hz:g};n={n_req}")


def bench_pool_scaleout(smoke: bool = False):
    """Scale-out PR tentpole: the ``ShardedEnginePool`` (consistent-hash
    placement over a host group, absorb fan-out, cross-host re-selection
    reads, replicated last-good slabs) under open-loop load WHILE a
    seeded schedule kills an owner host mid-stream, followed by a
    rebalance (checkpoint + WAL rebuild of the dead host's shards).
    Reports availability = (FRESH + STALE) / reads — the CI scaleout
    gate asserts >= 0.99 — and ``bitsame``: post-rebalance answers must
    be BIT-IDENTICAL to a never-failed single-host union engine (=1)."""
    import tempfile

    from repro.launch.pool import (FRESH, REJECTED, STALE, RejectedError,
                                   ShardedEnginePool)
    from repro.launch.query import SegmentQueryEngine
    from tests.faults import FaultInjector, poisson_arrivals

    n_ops = 60 if smoke else 240
    rate_hz = 20.0 if smoke else 100.0
    shards, rows = 16, 128 if smoke else 512
    kk = 16 if smoke else 64
    rng = np.random.default_rng(31)
    spec = C.MultiSketchSpec(objectives=((C.SUM, kk), (C.COUNT, kk)),
                             seed=0, capacity=4 * kk)
    with tempfile.TemporaryDirectory() as dur:
        pool = ShardedEnginePool(hosts=(0, 1, 2, 3), durability_dir=dur,
                                 pending_limit=1024, sleep=lambda s: None)
        placement = pool.create_stream("t", spec, shards=shards)
        twin = SegmentQueryEngine(spec, shards=shards)
        statuses = {FRESH: 0, STALE: 0, REJECTED: 0}
        unlabeled = shed = 0
        lat_ms = []
        arrivals = poisson_arrivals(rate_hz, n_ops, rng)
        t0 = time.perf_counter()
        with FaultInjector(seed=32) as inj:
            inj.kill_host(pool, placement[0], at=n_ops // 2)
            for i in range(n_ops):
                sched = t0 + float(arrivals[i])
                while True:             # open-loop: hold to the schedule
                    gap = sched - time.perf_counter()
                    if gap <= 0:
                        break
                    time.sleep(min(gap, 1e-3))
                sh = int(rng.integers(0, shards))
                keys = (i * rows + np.arange(rows)).astype(np.int32)
                w = rng.lognormal(0, 1.5, rows).astype(np.float32)
                try:
                    pool.absorb("t", keys, w, shard=sh)
                except RejectedError:
                    shed += 1
                    continue
                twin.absorb(keys, w, shard=sh)
                r = pool.query("t", timeout=2.0)
                statuses[r.status] += 1
                lat_ms.append((time.perf_counter() - sched) * 1e3)
                if r.status == FRESH:
                    if (r.epoch_lag != 0 or not np.array_equal(
                            r.values, twin.query_many())):
                        unlabeled += 1  # FRESH must be the exact truth
                elif r.status == STALE:
                    if r.values is None or (r.epoch_lag == 0
                                            and r.error is None):
                        unlabeled += 1  # degraded must be labeled
        # recovery: re-partition around the dead host, answers exact again
        reb_t0 = time.perf_counter()
        out = pool.rebalance("t")["t"]
        reb_ms = (time.perf_counter() - reb_t0) * 1e3
        r = pool.query("t")
        bitsame = int(r.status == FRESH and out["error"] is None
                      and np.array_equal(r.values, twin.query_many()))
        pool.close()
    reads = sum(statuses.values())
    avail = (statuses[FRESH] + statuses[STALE]) / max(reads, 1)
    lat = np.asarray(lat_ms)
    _record("pool_scaleout", float(np.percentile(lat, 95)) * 1e3,
            f"availability={avail:.4f};bitsame={bitsame};"
            f"unlabeled={unlabeled};fresh={statuses[FRESH]};"
            f"stale={statuses[STALE]};rejected={statuses[REJECTED]};"
            f"shed={shed};moved={len(out['moved'])};"
            f"rebalance_ms={reb_ms:.1f};"
            f"p50_ms={np.percentile(lat, 50):.2f};"
            f"p95_ms={np.percentile(lat, 95):.2f};"
            f"hosts=4;shards={shards};rate_hz={rate_hz:g};n={n_ops}")


def bench_dryrun_roofline_summary():
    """Ties to EXPERIMENTS.md §Roofline: summarize dry-run artifacts."""
    import glob
    import json
    for mesh in ("sp", "mp"):
        cells = ok = 0
        for f in glob.glob(f"experiments/dryrun/*__{mesh}.json"):
            r = json.load(open(f))
            cells += 1
            ok += r.get("status") in ("ok", "skipped")
        _record(f"dryrun_cells_{mesh}", 0.0, f"total={cells};ok_or_skipped={ok}")


def bench_roofline_fold_model(smoke: bool = False):
    """Satellite: the idle roofline generator, wired into the registry —
    the absorb/fold bytes-moved model (benchmarks.roofline) for the
    serving engine's maintenance paths, plus the dry-run table row count
    when artifacts exist. ``--only roofline`` runs it standalone."""
    from benchmarks.roofline import HBM_BW, fold_bytes_moved
    spec = C.MultiSketchSpec(objectives=((C.SUM, 64), (C.COUNT, 64),
                                         (C.thresh(2.0), 64)), seed=0)
    b = C.multisketch_slab_bytes(spec)
    for absorb_time in (True, False):
        mode = "absorb_time" if absorb_time else "lazy"
        m = fold_bytes_moved(b, chunk_rows=8192, num_shards=8,
                             absorb_time=absorb_time)
        _record(f"roofline_fold_{mode}", m["min_epoch_s"] * 1e6,
                f"slab_bytes={b};epoch_bytes={m['epoch_bytes']};"
                f"shard_fold_bytes={m['shard_fold_bytes']};"
                f"maintain_bytes={m['maintain_bytes']};"
                f"lazy_remerge_bytes={m['lazy_remerge_bytes']};"
                f"hbm_bw={HBM_BW:g}")


def _registry(smoke: bool):
    """Bench registry: (name, thunk, runs_in_smoke). ``--only <name>``
    selects one entry (running it even when the smoke subset skips it)."""
    s = dict(smoke=smoke)
    return (
        ("example_2_1_pps_table", bench_example_2_1_pps_table, True),
        ("example_3_1_multiobjective_size",
         bench_example_3_1_multiobjective_size, True),
        ("thm_5_1_universal_size", bench_thm_5_1_universal_size, False),
        ("thm_6_1_capping_size", bench_thm_6_1_capping_size, False),
        ("thm_3_1_estimation_cv", bench_thm_3_1_estimation_cv, False),
        ("sampling_throughput", bench_sampling_throughput, False),
        ("merge_throughput", bench_merge_throughput, True),
        ("incremental_merge", partial(bench_incremental_merge, **s), True),
        ("absorb_throughput", partial(bench_absorb_throughput, **s), True),
        ("universal_scan", partial(bench_universal_scan, **s), True),
        ("query_engine", partial(bench_query_engine, **s), True),
        ("cluster_engine", partial(bench_cluster_engine, **s), True),
        ("engine_tail_latency",
         partial(bench_engine_tail_latency, **s), True),
        ("shard_gc", partial(bench_shard_gc, **s), True),
        ("roofline", bench_roofline_fold_model, True),
        ("serving_chaos", partial(bench_serving_chaos, **s), True),
        ("pool_scaleout", partial(bench_pool_scaleout, **s), True),
        ("gradient_compression", bench_gradient_compression, True),
        ("multiobj_scaling", bench_multiobj_scaling, False),
        ("dryrun_roofline_summary", bench_dryrun_roofline_summary, True),
    )


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced fast subset (CI): skips the scaling "
                         "sweeps, shrinks the absorb bench")
    ap.add_argument("--only", default=None,
                    help="run a single bench by registry name "
                         "(e.g. serving_chaos)")
    ap.add_argument("--out", default="BENCH_results.json",
                    help="JSON results path")
    args = ap.parse_args(argv)
    registry = _registry(args.smoke)
    if args.only is not None:
        names = {n for n, _, _ in registry}
        if args.only not in names:
            raise SystemExit(f"unknown bench {args.only!r}; "
                             f"choose from {sorted(names)}")
    print("name,us_per_call,derived")
    for name, fn, in_smoke in registry:
        if args.only is not None:
            if name != args.only:
                continue
        elif args.smoke and not in_smoke:
            continue
        fn()
    with open(args.out, "w") as fh:
        json.dump({"us_per_call": RESULTS, "derived": DERIVED}, fh,
                  indent=1, sort_keys=True)
    print(f"# wrote {args.out} ({len(RESULTS)} entries)")


if __name__ == "__main__":
    main()
