"""Roofline table generator: experiments/dryrun/*.json -> markdown.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Terms (per device == per chip, post-SPMD HLO):
    compute    = flops / 197e12
    memory     = hbm_bytes / 819e9
    collective = coll_bytes / 50e9
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per chip for train cells;
forward-only cells use 2*N*D. The useful-fraction column flags remat/
replication waste. Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--mesh sp|mp]
"""
from __future__ import annotations

import argparse
import glob
import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_per_chip(arch: str, shape_name: str, chips: int) -> float:
    from repro.configs.registry import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        mult = 6
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        mult = 2
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2
    return mult * n * tokens / chips


def load_rows(mesh_tag: str):
    rows = []
    for f in sorted(glob.glob(f"experiments/dryrun/*__{mesh_tag}.json")):
        r = json.load(open(f))
        rows.append(r)
    return rows


def render(mesh_tag: str = "sp", fmt: str = "md"):
    chips = 256 if mesh_tag == "sp" else 512
    rows = load_rows(mesh_tag)
    out = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS/HLO | temp GB | fits | note |")
    out.append(hdr)
    out.append("|" + "---|" * 10)
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — |"
                       f" — | — | SKIP: {r['reason']} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — |"
                       f" — | — | {r['status']} |")
            continue
        h = r["hlo_cost"]
        ct = h["flops"] / PEAK_FLOPS
        mt = h["hbm_bytes"] / HBM_BW
        lt = h["coll_bytes"] / ICI_BW
        dom = max((("compute", ct), ("memory", mt), ("collective", lt)),
                  key=lambda x: x[1])[0]
        mf = model_flops_per_chip(r["arch"], r["shape"], chips)
        useful = mf / max(h["flops"], 1)
        temp = r["memory"]["temp_size_in_bytes"] / 1e9
        args = r["memory"]["argument_size_in_bytes"] / 1e9
        fits = "yes" if (temp + args) < 17.18 else f"NO ({temp+args:.0f}GB)"  # 16 GiB HBM
        mb = r.get("microbatch", 0)
        note = f"mb={mb}" if mb and mb > 1 else ""
        if r.get("overrides"):
            note += f" {r['overrides']}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {ct:.3f} | {mt:.3f} | {lt:.3f} "
            f"| {dom} | {useful:.2f} | {temp:.1f} | {fits} | {note} |")
    return "\n".join(out)


def fold_bytes_moved(slab_bytes: int, chunk_rows: int, num_shards: int,
                     absorb_time: bool = True) -> dict:
    """Bytes-moved model for ONE absorb epoch of the serving engine
    (launch.query), in the roofline's memory term.

    The shard fold reads the target shard's slab plus the chunk
    (int32 key + float32 weight + bool active = 9 B/row) and writes the
    slab back; absorb-time maintenance adds the merged-slab delta fold
    (read merged + the post-fold shard slab, write merged). The lazy
    engine instead pays a full stacked re-merge at the NEXT query: read
    all ``num_shards`` slabs, write one. Every fold is re-selection-
    bound, so bytes/HBM_BW is the floor for the epoch's device time.
    """
    chunk_bytes = 9 * chunk_rows
    shard_fold = 2 * slab_bytes + chunk_bytes
    maintain = 3 * slab_bytes if absorb_time else 0
    lazy_remerge = 0 if absorb_time else (num_shards + 1) * slab_bytes
    total = shard_fold + maintain + lazy_remerge
    return {
        "shard_fold_bytes": shard_fold,
        "maintain_bytes": maintain,
        "lazy_remerge_bytes": lazy_remerge,
        "epoch_bytes": total,
        "min_epoch_s": total / HBM_BW,
    }


def render_fold_model() -> str:
    """Markdown table of the absorb/fold bytes-moved model across the
    serving configurations the benches exercise."""
    from repro.core import (COUNT, SUM, MultiSketchSpec, multisketch_slab_bytes,
                            thresh)
    spec = MultiSketchSpec(objectives=((SUM, 64), (COUNT, 64),
                                       (thresh(2.0), 64)), seed=0)
    b = multisketch_slab_bytes(spec)
    out = ["| mode | shards | chunk | epoch bytes | min epoch time |",
           "|" + "---|" * 5]
    for absorb_time in (True, False):
        for shards in (2, 8):
            for chunk in (2048, 8192):
                m = fold_bytes_moved(b, chunk, shards, absorb_time)
                mode = "absorb-time" if absorb_time else "lazy"
                out.append(f"| {mode} | {shards} | {chunk} "
                           f"| {m['epoch_bytes']} "
                           f"| {m['min_epoch_s']*1e9:.1f} ns |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="sp", choices=("sp", "mp"))
    ap.add_argument("--fold-model", action="store_true",
                    help="print the absorb/fold bytes-moved model instead "
                         "of the dry-run table")
    args = ap.parse_args()
    if args.fold_model:
        print(render_fold_model())
    else:
        print(render(args.mesh))


if __name__ == "__main__":
    main()
